//! Section 6.3 — approximate merging: trade clustering quality for
//! performance by probabilistically dropping merge operations (the
//! loop-perforation-style merge function).
//!
//!     cargo run --release --example approx_kmeans

use ccache::coordinator::scaled_config;
use ccache::exec::{Variant, WorkloadHandle};
use ccache::util::bench::Table;
use ccache::workloads::kmeans::{KmParams, KmWorkload};

fn main() {
    let cfg = scaled_config();
    let points = cfg.llc().size_bytes / (16 * 4); // WS ~ LLC
    let mut t = Table::new(
        "approximate K-Means: drop probability vs quality/performance",
        &["drop_p", "cycles", "speedup", "quality degradation"],
    );
    let mut base_cycles = 0u64;
    for drop_p in [0.0f32, 0.05, 0.1, 0.25, 0.5] {
        let p = KmParams {
            points,
            clusters: 4,
            iters: 3,
            seed: 9,
            approx_drop_p: drop_p,
        };
        eprintln!("running drop_p={drop_p}...");
        let r = WorkloadHandle::new(KmWorkload::new(p))
            .run(Variant::CCache, cfg.clone())
            .expect("ccache variant is supported");
        assert!(r.verified, "clustering collapsed at drop_p={drop_p}");
        if drop_p == 0.0 {
            base_cycles = r.cycles();
        }
        t.row(&[
            format!("{drop_p:.2}"),
            r.cycles().to_string(),
            format!("{:.2}x", base_cycles as f64 / r.cycles() as f64),
            r.quality
                .map(|q| format!("{:+.1}%", q * 100.0))
                .unwrap_or_else(|| "exact".into()),
        ]);
    }
    t.print();
    println!(
        "the paper reports ~20% intra-cluster-distance degradation when\n\
         dropping 10% of merges — quality-performance trade-offs are a\n\
         merge-function-level decision in CCache."
    );
}
