//! Graph analytics on the simulated machine + the PJRT compute path.
//!
//! Part 1 — simulator: PageRank and BFS across the paper's graph inputs
//! (RMAT / SSCA / uniform), comparing FGL / DUP / CCache / atomics.
//!
//! Part 2 — three-layer composition: the same PageRank numerics executed
//! through the AOT-compiled Pallas kernel (`artifacts/pagerank_iter`)
//! via PJRT, cross-checked against the simulator's final ranks.
//!
//!     cargo run --release --example graph_analytics

use ccache::coordinator::{run_verified, scaled_config};
use ccache::exec::{Variant, WorkloadHandle};
use ccache::runtime;
use ccache::util::bench::Table;
use ccache::workloads::bfs::{BfsParams, BfsWorkload};
use ccache::workloads::graph::GraphKind;
use ccache::workloads::pagerank::{PrParams, PrWorkload};

fn main() {
    let cfg = scaled_config();

    // ---- part 1: simulator comparison ----
    let mut t = Table::new(
        "PageRank / BFS: speedup vs FGL per input graph",
        &["benchmark", "FGL cycles", "DUP", "CCACHE", "ATOMIC"],
    );
    for kind in [GraphKind::Rmat, GraphKind::Ssca, GraphKind::Uniform] {
        let p = PrParams {
            vertices: cfg.llc().size_bytes / 64,
            avg_degree: 8,
            graph: kind,
            iters: 2,
            damping: 0.85,
            seed: 11,
        };
        let bench = WorkloadHandle::new(PrWorkload::new(p));
        eprintln!("running {}...", bench.name());
        let fgl = run_verified(&bench, Variant::Fgl, &cfg);
        let dup = run_verified(&bench, Variant::Dup, &cfg);
        let cc = run_verified(&bench, Variant::CCache, &cfg);
        t.row(&[
            bench.name().to_string(),
            fgl.cycles().to_string(),
            format!("{:.2}x", fgl.cycles() as f64 / dup.cycles() as f64),
            format!("{:.2}x", fgl.cycles() as f64 / cc.cycles() as f64),
            "-".into(),
        ]);
    }
    for kind in [GraphKind::Rmat, GraphKind::Uniform] {
        let p = BfsParams {
            vertices: cfg.llc().size_bytes / 48,
            avg_degree: 8,
            graph: kind,
            seed: 13,
            source: 0,
        };
        let bench = WorkloadHandle::new(BfsWorkload::new(p));
        eprintln!("running {}...", bench.name());
        let fgl = run_verified(&bench, Variant::Fgl, &cfg);
        let dup = run_verified(&bench, Variant::Dup, &cfg);
        let cc = run_verified(&bench, Variant::CCache, &cfg);
        let at = run_verified(&bench, Variant::Atomic, &cfg);
        t.row(&[
            bench.name().to_string(),
            fgl.cycles().to_string(),
            format!("{:.2}x", fgl.cycles() as f64 / dup.cycles() as f64),
            format!("{:.2}x", fgl.cycles() as f64 / cc.cycles() as f64),
            format!("{:.2}x", fgl.cycles() as f64 / at.cycles() as f64),
        ]);
    }
    t.print();

    // ---- part 2: PJRT numeric cross-check ----
    if !runtime::artifacts::artifacts_available() {
        println!("(skipping PJRT cross-check: run `make artifacts`)");
        return;
    }
    println!("\nPJRT cross-check: PageRank through the Pallas kernel");
    let v = 512usize;
    let p = PrParams {
        vertices: v,
        avg_degree: 8,
        graph: GraphKind::Uniform,
        iters: 1,
        damping: 0.85,
        seed: 11,
    };
    let g = p.build_graph();
    // dense normalized adjacency for the kernel, padded V inside Engine
    let pad_v = runtime::artifacts::PAGERANK_V;
    let mut adj = vec![vec![0f32; v]; v];
    for src in 0..v {
        for &dst in g.neighbors(src) {
            adj[dst as usize][src] += 1.0;
        }
    }
    let out_deg_inv: Vec<f32> = (0..v)
        .map(|u| {
            let d = g.out_degree(u);
            if d > 0 {
                1.0 / d as f32
            } else {
                0.0
            }
        })
        .collect();
    let rank = vec![1.0f32 / pad_v as f32; v];

    let mut engine = runtime::Engine::load_default().expect("engine");
    let got = engine
        .pagerank_iter(&adj, &rank, &out_deg_inv)
        .expect("pagerank_iter");

    // reference with the same padded-V damping constant
    let mut want = vec![(1.0 - 0.85) / pad_v as f32; v];
    for src in 0..v {
        if g.out_degree(src) == 0 {
            continue;
        }
        let c = rank[src] * out_deg_inv[src];
        for &dst in g.neighbors(src) {
            want[dst as usize] += 0.85 * c;
        }
    }
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!(
        "  kernel vs reference: max abs err = {max_err:.2e} over {v} vertices"
    );
    assert!(max_err < 1e-5, "PJRT kernel diverged");
    println!("  three-layer composition verified (JAX/Pallas -> HLO -> rust PJRT).");
}
