//! A user-supplied merge function, end to end — the openness proof for
//! the merge API (paper Sections 3.2/4.5: software merge functions make
//! commutative-update acceleration broadly applicable).
//!
//! This example defines a brand-new merge function *outside* the crate's
//! `merge/` module, registers it through the public `MergeRegistry` API,
//! law-checks it with the auto-generated property suite, and runs the
//! kvstore workload with it installed in the MFRF — passing the same
//! golden verification the built-ins pass. Nothing in `ccache::merge`
//! names this type: adding a merge behaviour requires zero edits to the
//! crate.
//!
//!     cargo run --release --example custom_merge

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use ccache::coordinator::scaled_config;
use ccache::exec::registry::{self, SizeSpec};
use ccache::exec::Variant;
use ccache::merge::{handle, LineData, MergeFn, MergeHandle, MergeRegistry, LINE_WORDS};
use ccache::util::ptest::check_merge_fn_laws;

/// An *instrumented* additive merge: semantically `mem += upd - src`
/// (so kvstore's increment workload verifies bit-for-bit against its
/// sequential golden run), but it also observes the merge stream —
/// counting merged lines and the largest single-line delta. Software
/// merge functions can carry state and side observations; a closed
/// hardware enum cannot.
#[derive(Default)]
struct AuditedAddU32 {
    lines_merged: AtomicU64,
    max_delta: AtomicU64,
}

impl MergeFn for AuditedAddU32 {
    fn name(&self) -> &str {
        "audited_add_u32"
    }

    fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        self.lines_merged.fetch_add(1, Ordering::Relaxed);
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            let delta = upd[i].wrapping_sub(src[i]);
            self.max_delta.fetch_max(delta as u64, Ordering::Relaxed);
            out[i] = mem[i].wrapping_add(delta);
        }
        out
    }
}

fn main() {
    // 1. register the function through the public API, exactly like a
    //    built-in (the name becomes CLI-selectable on a custom binary)
    let mut reg = MergeRegistry::with_builtins();
    reg.register("audited_add_u32", "add with merge auditing", |_| {
        Ok(handle(AuditedAddU32::default()))
    });
    println!("registered merge functions: {}", reg.names().join(", "));

    // 2. the auto-generated law suite checks commutativity for free
    check_merge_fn_laws(&AuditedAddU32::default(), 0xC0FFEE, 50);
    println!("law suite: audited_add_u32 is commutative");

    // 3. run the kvstore workload with the custom function installed in
    //    every MFRF slot; keep a handle to read the audit counters back
    let audited = Arc::new(AuditedAddU32::default());
    let installed: MergeHandle = audited.clone();

    let cfg = scaled_config();
    let size = SizeSpec::new(1.0, cfg.llc().size_bytes, 77);
    let bench = registry::build("kvstore", &size).expect("kvstore is registered");
    println!(
        "running {} / ccache with audited_add_u32 on {}...",
        bench.name(),
        cfg.describe()
    );
    let r = bench
        .run_with_merge(Variant::CCache, cfg, Some(installed))
        .expect("run");

    println!(
        "{}/ccache: {} cycles, verified={}, merges=[{}]",
        r.benchmark,
        r.cycles(),
        r.verified,
        r.merge_fns.join(", ")
    );
    println!(
        "audit: {} lines merged, largest single-lane delta {}",
        audited.lines_merged.load(Ordering::Relaxed),
        audited.max_delta.load(Ordering::Relaxed)
    );
    assert!(r.verified, "custom merge function diverged from golden");
    assert_eq!(
        audited.lines_merged.load(Ordering::Relaxed),
        r.stats.merges,
        "the user function ran once per simulator merge"
    );
    println!("OK — a user-defined MergeFn drove the full CCache pipeline.");
}
