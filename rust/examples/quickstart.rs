//! Quickstart: run the key-value store on the simulated 8-core machine
//! in all three execution strategies the paper compares, and print the
//! headline speedups.
//!
//!     cargo run --release --example quickstart

use ccache::coordinator::{run_verified, scaled_config, sized_workload};
use ccache::exec::Variant;
use ccache::util::bench::Table;

fn main() {
    let cfg = scaled_config();
    // a working set matching LLC capacity — the paper's sweet spot
    let bench = sized_workload("kvstore", 1.0, cfg.llc().size_bytes, 42);
    println!(
        "benchmark: {} ({} cores, {} KiB LLC)\n",
        bench.name(),
        cfg.cores,
        cfg.llc().size_bytes / 1024
    );

    let mut results = Vec::new();
    for v in [Variant::Fgl, Variant::Dup, Variant::CCache] {
        eprintln!("running {}...", v.name());
        results.push(run_verified(&bench, v, &cfg));
    }

    let fgl = results[0].cycles() as f64;
    let mut t = Table::new(
        "key-value store — cycles and speedup vs FGL",
        &["variant", "cycles", "speedup", "LLC miss%", "merges"],
    );
    for r in &results {
        t.row(&[
            r.variant.name().to_string(),
            r.cycles().to_string(),
            format!("{:.2}x", fgl / r.cycles() as f64),
            format!("{:.1}", r.stats.llc().miss_rate() * 100.0),
            r.stats.merges.to_string(),
        ]);
    }
    t.print();
    println!("all variants verified against the sequential golden run.");
}
