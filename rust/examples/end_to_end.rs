//! End-to-end driver: exercises the full three-layer system on real
//! (small) workloads and reports the paper's headline metric — CCache's
//! speedup over FGL and DUP — plus a cross-layer merge validation.
//!
//! What runs:
//!  1. The registered benchmark suite (KV store, K-Means, PageRank, BFS,
//!     histogram) in FGL / DUP / CCache at a working set matching the
//!     LLC, on the simulated 8-core Table 2 machine (scaled). Every run
//!     is verified against its sequential golden run.
//!  2. Merge-path validation: a CCache run with merge recording on; the
//!     recorded (src, upd, mem) line triples are re-executed through the
//!     AOT-compiled Pallas merge kernels via PJRT and compared with the
//!     native merge path bit-for-bit.
//!
//!     cargo run --release --example end_to_end

use ccache::coordinator::{scaled_config, sized_workload};
use ccache::exec::Variant;
use ccache::merge::batch::{BatchExecutor, NativeExecutor};
use ccache::merge::funcs::AddU32;
use ccache::merge::handle;
use ccache::runtime;
use ccache::sim::machine::{CoreCtx, Machine};
use ccache::util::bench::Table;

fn main() {
    let cfg = scaled_config();
    println!("== end-to-end: {} ==\n", cfg.describe());

    // ---- 1. the benchmark suite ----
    let mut t = Table::new(
        "headline: speedup vs FGL at working set = LLC capacity",
        &["benchmark", "FGL Mcycles", "DUP", "CCACHE", "verified"],
    );
    let panels = [
        "kvstore",
        "kmeans",
        "pagerank-uniform",
        "pagerank-rmat",
        "bfs-rmat",
        "histogram",
    ];
    let mut ccache_speedups = Vec::new();
    for name in panels {
        let bench = sized_workload(name, 1.0, cfg.llc().size_bytes, 77);
        eprintln!("running {}...", bench.name());
        let run = |v: Variant| bench.run(v, cfg.clone()).expect("supported variant");
        let fgl = run(Variant::Fgl);
        let dup = run(Variant::Dup);
        let cc = run(Variant::CCache);
        let all_ok = fgl.verified && dup.verified && cc.verified;
        let s_cc = fgl.cycles() as f64 / cc.cycles() as f64;
        ccache_speedups.push(s_cc);
        t.row(&[
            bench.name().to_string(),
            format!("{:.1}", fgl.cycles() as f64 / 1e6),
            format!("{:.2}x", fgl.cycles() as f64 / dup.cycles() as f64),
            format!("{s_cc:.2}x"),
            all_ok.to_string(),
        ]);
        assert!(all_ok, "verification failed for {}", bench.name());
    }
    t.print();
    let best = ccache_speedups.iter().cloned().fold(0.0, f64::max);
    println!(
        "max CCache speedup over FGL: {best:.2}x (paper: up to 3.2x on its testbed)\n"
    );

    // ---- 2. merge-path validation through PJRT ----
    if !runtime::artifacts::artifacts_available() {
        println!("(skipping PJRT merge validation: run `make artifacts`)");
        return;
    }
    println!("merge-path validation: native vs AOT Pallas kernels (PJRT)");
    let cores = cfg.cores;
    let machine = Machine::new(cfg).expect("valid config");
    let region = machine.setup(|mem| {
        mem.record_merges = true;
        let r = mem.alloc_lines(64 * 4096);
        for i in 0..4096u64 {
            mem.poke(r.add(i * 64), (i % 97) as u32);
        }
        r
    });
    let programs: Vec<Box<dyn FnOnce(&mut CoreCtx) + Send + '_>> = (0..cores)
        .map(|core| {
            let f: Box<dyn FnOnce(&mut CoreCtx) + Send + '_> = Box::new(move |ctx| {
                ctx.merge_init(0, handle(AddU32));
                let mut x = core as u64 + 1;
                for _ in 0..20_000 {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(97);
                    let k = (x >> 33) % 4096;
                    let a = region.add(k * 64 + ((x >> 20) % 16) * 4);
                    let v = ctx.c_read_u32(a, 0);
                    ctx.c_write_u32(a, v.wrapping_add(1), 0);
                    ctx.soft_merge();
                }
                ctx.merge();
            });
            f
        })
        .collect();
    machine.run(programs);

    let log = machine.setup(|mem| std::mem::take(&mut mem.merge_log));
    println!("  recorded {} line merges from the CCache run", log.len());
    let items: Vec<_> = log.iter().map(|r| r.item.clone()).collect();
    let native = NativeExecutor.execute(&AddU32, &items);
    let mut pjrt =
        runtime::PjrtMergeExecutor::load_default().expect("PJRT executor");
    let via_pjrt = pjrt.execute(&AddU32, &items);
    assert_eq!(native.len(), via_pjrt.len());
    let mismatches = native
        .iter()
        .zip(&via_pjrt)
        .filter(|(a, b)| a != b)
        .count();
    println!(
        "  native vs PJRT: {mismatches} mismatching lines of {}",
        native.len()
    );
    assert_eq!(mismatches, 0, "merge paths diverged");
    println!("  OK — the simulator's merge results are reproduced by the");
    println!("  AOT-compiled JAX/Pallas kernels executed from rust via PJRT.");
}
