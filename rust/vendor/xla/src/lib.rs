//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The build environment has no registry access and no `xla_extension`
//! shared library, so this stub provides the exact API surface the
//! `ccache::runtime` layer uses — enough to *compile* it — while every
//! entry point that would touch PJRT returns an error at runtime.
//! Callers already gate on `artifacts_available()` / `Engine::load`
//! results, so the simulator, workloads and native merge path are fully
//! functional; only the optional PJRT cross-check is disabled. Replace
//! this path dependency with the real `xla` crate to enable it.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT/XLA is not available in this build (offline `xla` stub; \
         replace rust/vendor/xla with the real xla crate to enable)"
    )))
}

pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<A>(&self, _args: &[A]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation {}
    }
}

pub struct Literal {}

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal {}
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        let lit = Literal::vec1(&[1f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = HloModuleProto::from_text_file("x").unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
