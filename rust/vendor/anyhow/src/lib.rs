//! Offline stand-in for the `anyhow` crate, implementing the subset of
//! its API this workspace uses: [`Error`], [`Result`], the [`Context`]
//! extension trait (on both `Result` and `Option`), and the `anyhow!`,
//! `bail!` and `ensure!` macros.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `impl<E: std::error::Error> From<E> for Error` coherent. The cause
//! chain is flattened to strings at construction; `{e}` prints the
//! outermost message and `{e:#}` the full `a: b: c` chain.

use std::fmt;

/// A flattened error chain: `chain[0]` is the outermost context.
pub struct Error {
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a plain message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error in one more layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
        assert_eq!(Some(7u32).context("empty").unwrap(), 7);
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(format!("{}", f(12).unwrap_err()).contains("12"));
        assert!(format!("{}", f(5).unwrap_err()).contains("Condition failed"));
        assert!(f(3).is_err());
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}
