//! The composable hierarchy walk ([`AccessPath`]) exercised directly:
//! per-level latency accounting, innermost-fill hand-off, and the
//! outermost-private-level directory discipline that distinguishes the
//! 2-level shape from the 3-level one. Engine-level behaviour is in
//! `tests/protocol.rs` / `tests/mesi.rs`.

use ccache::sim::addr::Line;
use ccache::sim::config::MachineConfig;
use ccache::sim::hierarchy::path::AccessPath;
use ccache::sim::hierarchy::{LevelConfig, ProtocolKind, Timing};
use ccache::sim::stats::Stats;

fn path(cfg: &MachineConfig) -> (AccessPath, Stats) {
    (AccessPath::new(cfg), Stats::new(cfg.cores, cfg.depth()))
}

#[test]
fn three_level_walk_charges_every_level() {
    let cfg = MachineConfig::test_small();
    let (mut p, mut stats) = path(&cfg);
    let w = p.coherent_walk(0, Line(4), false, &mut stats);
    assert_eq!(w.cycles, 4 + 10 + 70 + 300);
    assert!(w.fill.is_some());
    assert_eq!(stats.levels[0].misses, 1);
    assert_eq!(stats.levels[1].misses, 1);
    assert_eq!(stats.levels[2].misses, 1);
    assert_eq!(stats.mem_accesses, 1);
}

#[test]
fn two_level_walk_skips_the_middle() {
    let cfg = MachineConfig::test_small_2level();
    let (mut p, mut stats) = path(&cfg);
    assert_eq!(p.private_depth(), 1);
    let w = p.coherent_walk(0, Line(4), false, &mut stats);
    assert_eq!(w.cycles, 4 + 70 + 300);
    assert_eq!(stats.levels.len(), 2);
    assert_eq!(stats.levels[1].misses, 1);
}

#[test]
fn four_level_walk_charges_the_synthesized_l3() {
    let mut cfg = MachineConfig::test_small().with_depth(4).unwrap();
    cfg.mem_bytes = 8 << 20;
    cfg.validate().unwrap();
    let (mut p, mut stats) = path(&cfg);
    assert_eq!(p.depth(), 4);
    let l3_hit = cfg.level(2).hit_cycles;
    let w = p.coherent_walk(0, Line(4), false, &mut stats);
    assert_eq!(w.cycles, 4 + 10 + l3_hit + 70 + 300);
    assert_eq!(stats.levels.len(), 4);
    assert_eq!(stats.levels[2].misses, 1);
}

#[test]
fn innermost_fill_completes_the_walk() {
    let cfg = MachineConfig::test_small();
    let (mut p, mut stats) = path(&cfg);
    let w = p.coherent_walk(0, Line(4), false, &mut stats);
    let req = w.fill.unwrap();
    p.try_fill_innermost(0, Line(4), req.owned, req.dirty, &mut stats)
        .unwrap();
    // hot: innermost hit, no fill needed
    let w2 = p.coherent_walk(0, Line(4), false, &mut stats);
    assert_eq!(w2.cycles, 4);
    assert!(w2.fill.is_none());
    assert_eq!(stats.levels[0].hits, 1);
}

#[test]
fn outermost_private_eviction_notifies_directory_in_2_level() {
    // 2-level: evicting a line from L1 (the outermost private level)
    // must issue a directory put, unlike the 3-level machine where
    // the L2 keeps the registration alive.
    let cfg = MachineConfig::test_small_2level();
    let (mut p, mut stats) = path(&cfg);
    let sets = cfg.l1().sets() as u64;
    let ways = cfg.l1().ways as u64;
    // fill one L1 set past capacity with same-set lines
    for i in 0..=ways {
        let line = Line(i * sets);
        let w = p.coherent_walk(0, line, false, &mut stats);
        if let Some(req) = w.fill {
            p.try_fill_innermost(0, line, req.owned, req.dirty, &mut stats)
                .unwrap();
        }
    }
    // the first line was evicted and its registration released:
    // the directory no longer tracks core 0 for it
    let e = p.directory().entry(Line(0));
    assert!(
        e.map_or(true, |e| !e.is_sharer(0)),
        "directory still registers the evicted line"
    );
}

#[test]
fn custom_level_stacks_validate_and_build() {
    // a hand-built asymmetric stack: tiny L1, big shared level
    let cfg = MachineConfig {
        cores: 2,
        levels: vec![
            LevelConfig::new(512, 2, 2, false),
            LevelConfig::new(32 << 10, 8, 50, true),
        ],
        timing: Timing {
            mem_cycles: 150,
            quantum: 0,
            lock_backoff: 40,
            update_cycles: 10,
        },
        ccache: Default::default(),
        mem_bytes: 1 << 20,
        fast_path: true,
        protocol: ProtocolKind::Mesi,
    };
    cfg.validate().unwrap();
    let (mut p, mut stats) = path(&cfg);
    let w = p.coherent_walk(0, Line(4), false, &mut stats);
    assert_eq!(w.cycles, 2 + 50 + 150);
}
