//! Randomized stress of the COp / coherent-access protocol engine,
//! checking the cross-structure invariants continuously.
//!
//! Legality discipline (the paper's Section 4.4 rule): while a region is
//! being manipulated with COps, no coherent access touches it; phase
//! boundaries (merge_all) separate the two access modes. The stress
//! driver alternates phases to exercise both transition directions, and
//! a multi-core variant checks that coherence actions never corrupt
//! another core's CData.

use ccache::merge::funcs::{AddU32, BitOr};
use ccache::merge::handle;
use ccache::sim::addr::Addr;
use ccache::sim::config::MachineConfig;
use ccache::sim::memsys::MemSystem;

fn lcg(x: &mut u64) -> u64 {
    *x = x
        .wrapping_mul(6364136223846793005)
        .wrapping_add(1442695040888963407);
    *x >> 16
}

#[test]
fn random_cop_coherent_phases_keep_invariants() {
    let mut cfg = MachineConfig::test_small();
    cfg.cores = 1;
    let mut s = MemSystem::new(cfg).unwrap();
    // same function in two MFRF slots: random re-typing between them
    // exercises the rebind path (L1 meta + source buffer must track,
    // invariant 5) without changing the additive results
    s.merge_init(0, 0, handle(AddU32));
    s.merge_init(0, 1, handle(AddU32));
    let cdata = s.alloc_lines(64 * 2048);
    let coh = s.alloc_lines(64 * 2048);
    let mut x: u64 = 12345;
    for phase in 0..40 {
        // COp phase on the cdata region + coherent ops elsewhere
        for op in 0..2_000 {
            if op % 500 == 499 {
                // mid-phase check: catches merge-type skew while lines
                // are still privatized (post-merge the buffer is empty
                // and invariant 5 is vacuous)
                s.check_invariants()
                    .unwrap_or_else(|e| panic!("phase {phase} mid-phase: {e}"));
            }
            let k = lcg(&mut x) % 2048;
            match lcg(&mut x) % 5 {
                0 | 1 => {
                    let ty = (lcg(&mut x) % 2) as u8;
                    let a = Addr(cdata.0 + k * 64);
                    let (v, _) = s.c_read(0, a, ty).unwrap();
                    s.c_write(0, a, v + 1, ty).unwrap();
                    // w-1 discipline: keep CData evictable
                    s.soft_merge(0).unwrap();
                }
                2 => {
                    s.soft_merge(0).unwrap();
                }
                3 => {
                    let _ = s.read(0, Addr(coh.0 + k * 64)).unwrap();
                }
                _ => {
                    s.write(0, Addr(coh.0 + k * 64), 7).unwrap();
                }
            }
        }
        s.merge_all(0).unwrap();
        s.check_invariants()
            .unwrap_or_else(|e| panic!("phase {phase} post-merge: {e}"));
        // transition phase: coherent sweep over part of the cdata region
        for i in 0..256u64 {
            let a = Addr(cdata.0 + i * 64);
            let v = s.peek(a);
            s.write(0, a, v).unwrap();
        }
        s.check_invariants()
            .unwrap_or_else(|e| panic!("phase {phase} post-sweep: {e}"));
    }
}

#[test]
fn multicore_cop_with_cross_core_coherent_traffic() {
    // Core 0 runs COps on a region it previously touched coherently;
    // core 1 hammers coherent lines in the same region's second half.
    // Regression test for the stale-directory-registration bug: a CData
    // line must never be invalidated by an incoming coherence message.
    let mut cfg = MachineConfig::test_small();
    cfg.cores = 2;
    let mut s = MemSystem::new(cfg).unwrap();
    s.merge_init(0, 0, handle(AddU32));
    let region = s.alloc_lines(64 * 512);
    let mut x = 99u64;
    // step 1: core 0 reads region coherently (directory registers it)
    for i in 0..512u64 {
        let _ = s.read(0, Addr(region.0 + i * 64)).unwrap();
    }
    // step 2: core 0 privatizes random lines in the first half; core 1
    // reads lines in the second half (invalidation-free but directory-
    // visible traffic)
    let mut expected = vec![0u32; 256];
    for _ in 0..20_000 {
        let k = lcg(&mut x) % 256;
        let a = Addr(region.0 + k * 64);
        match lcg(&mut x) % 4 {
            0 | 1 => {
                let (v, _) = s.c_read(0, a, 0).unwrap();
                s.c_write(0, a, v + 1, 0).unwrap();
                s.soft_merge(0).unwrap();
                expected[k as usize] += 1;
            }
            _ => {
                let k2 = 256 + (k % 256);
                let _ = s.read(1, Addr(region.0 + k2 * 64)).unwrap();
            }
        }
    }
    s.merge_all(0).unwrap();
    s.check_invariants().unwrap();
    // all of core 0's increments must have survived
    for k in 0..256u64 {
        let got = s.peek(Addr(region.0 + k * 64));
        assert_eq!(got, expected[k as usize], "line {k}");
    }
}

#[test]
fn retyping_a_privatized_line_merges_with_the_rebound_function() {
    // Regression for the merge-type rebind bug: the COp hit path rewrote
    // the L1 meta's merge-type field but left the source-buffer entry's
    // slot binding at the value captured at privatization, so the merge
    // engine resolved the *stale* function. Privatize under slot 0
    // (add_u32), re-type with slot 1 (bitor), merge: the values are
    // chosen so the two functions disagree — bitor gives 8 | 3 = 11, the
    // stale add gave 8 + (3 - 8) = 3.
    let mut cfg = MachineConfig::test_small();
    cfg.cores = 1;
    let mut s = MemSystem::new(cfg).unwrap();
    s.merge_init(0, 0, handle(AddU32));
    s.merge_init(0, 1, handle(BitOr));
    s.record_merges = true;
    let a = s.alloc_lines(64);
    s.poke(a, 8);
    // privatize under slot 0
    let (v, _) = s.c_read(0, a, 0).unwrap();
    assert_eq!(v, 8);
    // re-type the already-privatized line to slot 1 and update it
    s.c_write(0, a, 3, 1).unwrap();
    // both bindings must agree while the line is still privatized
    s.check_invariants().unwrap();
    s.merge_all(0).unwrap();
    assert_eq!(
        s.merge_log.len(),
        1,
        "exactly one line should have merged"
    );
    assert_eq!(
        s.merge_log[0].merge.name(),
        "bitor",
        "the merge engine must run the function the last COp named"
    );
    assert_eq!(s.peek(a), 8 | 3);
}

#[test]
fn cdata_survives_other_cores_writes_to_stale_registrations() {
    // The exact bug scenario: read coherently, privatize, then have
    // another core RFO the line while it sits in the source buffer.
    let mut cfg = MachineConfig::test_small();
    cfg.cores = 2;
    let mut s = MemSystem::new(cfg).unwrap();
    s.merge_init(0, 0, handle(AddU32));
    let a = s.alloc_lines(64);
    s.poke(a, 10);
    // core 0: coherent read (dir registers, granted E)
    let _ = s.read(0, a).unwrap();
    // core 0: privatize + update (transition cleans the registration)
    let (v, _) = s.c_read(0, a, 0).unwrap();
    s.c_write(0, a, v + 5, 0).unwrap();
    // core 1: write the same line — must not destroy core 0's CData
    s.write(1, a, 100).unwrap();
    s.check_invariants().unwrap();
    // core 0's merge applies its delta on top of core 1's write
    s.merge_all(0).unwrap();
    assert_eq!(s.peek(a), 105);
}

#[test]
fn partitioned_llc_keeps_invariants_under_reuse_aware_resizing() {
    use ccache::sim::hierarchy::level::PartitionPolicy;
    // Same phase discipline as the single-core stress, on an LLC whose
    // merge region the reuse-aware controller resizes mid-stream: the
    // partition invariant (CData-classed shared lines confined to the
    // merge-region ways, even right after a shrink demotes ways) is
    // checked continuously alongside invariants 1-6.
    let mut cfg = MachineConfig::test_small().with_partition(2, PartitionPolicy::ReuseAware);
    cfg.cores = 2;
    let mut s = MemSystem::new(cfg).unwrap();
    for core in 0..2 {
        s.merge_init(core, 0, handle(AddU32));
    }
    let cdata = s.alloc_lines(64 * 512);
    let coh = s.alloc_lines(64 * 512);
    let mut x: u64 = 777;
    for phase in 0..10 {
        for op in 0..1_500 {
            let core = (lcg(&mut x) % 2) as usize;
            let k = lcg(&mut x) % 512;
            match lcg(&mut x) % 5 {
                0 | 1 => {
                    let a = Addr(cdata.0 + k * 64);
                    let (v, _) = s.c_read(core, a, 0).unwrap();
                    s.c_write(core, a, v + 1, 0).unwrap();
                    // w-1 discipline: keep CData evictable
                    s.soft_merge(core).unwrap();
                }
                2 => {
                    let _ = s.read(core, Addr(coh.0 + k * 64)).unwrap();
                }
                3 => {
                    s.write(core, Addr(coh.0 + k * 64), 7).unwrap();
                }
                _ => {
                    s.soft_merge(core).unwrap();
                }
            }
            if op % 250 == 249 {
                s.check_invariants()
                    .unwrap_or_else(|e| panic!("phase {phase} mid-phase: {e}"));
            }
        }
        for core in 0..2 {
            s.merge_all(core).unwrap();
        }
        s.check_invariants()
            .unwrap_or_else(|e| panic!("phase {phase} post-merge: {e}"));
    }
    s.flush_hot_stats();
    // fill-heavy phases must have driven the controller: the recorded
    // way range proves the invariant was checked across resizes, not on
    // a statically-partitioned machine
    assert!(
        s.stats.repartitions > 0,
        "the controller never resized under 15k mixed ops"
    );
    assert!(s.stats.partition_ways_min >= 1);
    assert!(s.stats.partition_ways_max < 8, "merge region may never reach full associativity");
}
