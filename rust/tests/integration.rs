//! Cross-module integration tests on the full (small-scaled) machine:
//! the cross-cutting behaviours the paper claims. Per-benchmark
//! "every variant verifies" coverage is registry-driven and lives in
//! `tests/registry.rs`.

use ccache::coordinator::sized_workload;
use ccache::exec::{RunResult, Variant, WorkloadHandle};
use ccache::sim::config::MachineConfig;

fn cfg() -> MachineConfig {
    // a small but fully-shaped machine: 4 cores, real hierarchy
    let mut cfg = MachineConfig::default();
    cfg.cores = 4;
    cfg.l1_mut().size_bytes = 4 << 10;
    cfg.level_mut(1).size_bytes = 32 << 10;
    cfg.llc_mut().size_bytes = 256 << 10;
    cfg
}

fn run(bench: &WorkloadHandle, v: Variant) -> RunResult {
    run_on(bench, v, cfg())
}

fn run_on(bench: &WorkloadHandle, v: Variant, cfg: MachineConfig) -> RunResult {
    let r = bench.run(v, cfg).expect("variant supported");
    assert!(
        r.verified,
        "{} / {} diverged from the sequential golden run",
        r.benchmark,
        v.name()
    );
    r
}

#[test]
fn full_shape_machine_verifies_every_benchmark_and_variant() {
    // the same registry matrix as tests/registry.rs, but on the 4-core
    // fully-shaped hierarchy: catches core-count-dependent regressions
    // (reduction partitioning, frontier hand-off, termination flags)
    // that a 2-core machine cannot
    for spec in ccache::exec::registry::registry() {
        let bench = sized_workload(spec.name, 0.125, cfg().llc().size_bytes, 3);
        for &v in bench.supported_variants() {
            run(&bench, v);
        }
    }
}

#[test]
fn histogram_skew_verifies_on_full_shape_machine() {
    use ccache::exec::registry::{self, SizeSpec};
    let size = SizeSpec::new(0.125, cfg().llc().size_bytes, 3).with_zipf(0.9);
    let bench = registry::build("histogram", &size).unwrap();
    for v in [Variant::Fgl, Variant::CCache, Variant::Atomic] {
        run(&bench, v);
    }
}

// ---------------------------------------------------------------------
// cross-cutting claims
// ---------------------------------------------------------------------

#[test]
fn ccache_generates_far_fewer_invalidations_than_fgl() {
    let b = sized_workload("kvstore", 0.5, cfg().llc().size_bytes, 9);
    let cc = run(&b, Variant::CCache);
    let fgl = run(&b, Variant::Fgl);
    assert!(
        cc.stats.invalidations * 10 < fgl.stats.invalidations.max(10),
        "ccache invalidations {} vs fgl {}",
        cc.stats.invalidations,
        fgl.stats.invalidations
    );
}

#[test]
fn memory_footprint_ordering_matches_table3() {
    // FGL > DUP > CCache for the KV store (Table 3: 12x / 8x / 1x)
    let b = sized_workload("kvstore", 0.5, cfg().llc().size_bytes, 9);
    let fgl = run(&b, Variant::Fgl).stats.bytes_allocated;
    let dup = run(&b, Variant::Dup).stats.bytes_allocated;
    let cc = run(&b, Variant::CCache).stats.bytes_allocated;
    assert!(fgl > dup, "FGL {fgl} <= DUP {dup}");
    assert!(dup > cc, "DUP {dup} <= CCache {cc}");
    let f = fgl as f64 / cc as f64;
    assert!(f > 5.0 && f < 20.0, "FGL ratio {f}");
}

#[test]
fn merge_on_evict_reduces_kmeans_evictions_dramatically() {
    // Fig 9's key datapoint
    let b = sized_workload("kmeans", 0.25, cfg().llc().size_bytes, 9);
    let with = run(&b, Variant::CCache);
    let mut no = cfg();
    no.ccache.merge_on_evict = false;
    let without = run_on(&b, Variant::CCache, no);
    assert!(
        without.stats.src_buf_evictions > with.stats.src_buf_evictions.max(1) * 50,
        "no-opt {} vs opt {}",
        without.stats.src_buf_evictions,
        with.stats.src_buf_evictions
    );
}

#[test]
fn dirty_merge_cuts_pagerank_merges() {
    // Section 6.4: PageRank reads much CData it never updates
    let b = sized_workload("pagerank-uniform", 0.5, cfg().llc().size_bytes, 9);
    let with = run(&b, Variant::CCache);
    let mut no = cfg();
    no.ccache.dirty_merge = false;
    let without = run_on(&b, Variant::CCache, no);
    assert!(
        without.stats.merges >= with.stats.merges,
        "dirty-merge increased merges?!"
    );
}

#[test]
fn deterministic_stats_across_runs() {
    let b = sized_workload("kvstore", 0.25, cfg().llc().size_bytes, 5);
    let a = run(&b, Variant::CCache);
    let c = run(&b, Variant::CCache);
    assert_eq!(a.cycles(), c.cycles());
    assert_eq!(a.stats.merges, c.stats.merges);
    assert_eq!(a.stats.llc().misses, c.stats.llc().misses);
}
