//! Cross-module integration tests: every benchmark x variant verifies
//! against its sequential golden run on the full (small-scaled) machine,
//! plus cross-cutting behaviours the paper claims.

use ccache::coordinator::{sized_benchmark, BenchKind};
use ccache::exec::Variant;
use ccache::sim::config::MachineConfig;
use ccache::workloads::graph::GraphKind;
use ccache::workloads::Benchmark;

fn cfg() -> MachineConfig {
    // a small but fully-shaped machine: 4 cores, real hierarchy
    let mut cfg = MachineConfig::default();
    cfg.cores = 4;
    cfg.l1.size_bytes = 4 << 10;
    cfg.l2.size_bytes = 32 << 10;
    cfg.llc.size_bytes = 256 << 10;
    cfg
}

fn all_verify(bench: Benchmark) {
    for v in bench.variants() {
        if v == Variant::Cgl && !matches!(bench, Benchmark::Kv(_)) {
            continue;
        }
        let r = bench.run(v, cfg());
        assert!(
            r.verified,
            "{} / {} diverged from the sequential golden run",
            r.benchmark,
            v.name()
        );
    }
}

#[test]
fn kvstore_all_variants_verify() {
    all_verify(sized_benchmark(BenchKind::KvAdd, 0.5, cfg().llc.size_bytes, 3));
}

#[test]
fn kvstore_sat_all_variants_verify() {
    all_verify(sized_benchmark(BenchKind::KvSat, 0.5, cfg().llc.size_bytes, 3));
}

#[test]
fn kvstore_cmul_all_variants_verify() {
    all_verify(sized_benchmark(BenchKind::KvCmul, 0.25, cfg().llc.size_bytes, 3));
}

#[test]
fn kmeans_all_variants_verify() {
    all_verify(sized_benchmark(BenchKind::KMeans, 0.5, cfg().llc.size_bytes, 3));
}

#[test]
fn kmeans_approx_verifies_with_bounded_quality() {
    let b = sized_benchmark(BenchKind::KMeansApprox, 0.5, cfg().llc.size_bytes, 3);
    let r = b.run(Variant::CCache, cfg());
    assert!(r.verified);
    assert!(r.quality.is_some());
}

#[test]
fn pagerank_all_graphs_all_variants_verify() {
    for g in [GraphKind::Rmat, GraphKind::Ssca, GraphKind::Uniform] {
        all_verify(sized_benchmark(
            BenchKind::PageRank(g),
            0.5,
            cfg().llc.size_bytes,
            3,
        ));
    }
}

#[test]
fn bfs_all_graphs_all_variants_verify() {
    for g in [GraphKind::Rmat, GraphKind::Uniform] {
        all_verify(sized_benchmark(
            BenchKind::Bfs(g),
            0.5,
            cfg().llc.size_bytes,
            3,
        ));
    }
}

// ---------------------------------------------------------------------
// cross-cutting claims
// ---------------------------------------------------------------------

#[test]
fn ccache_generates_far_fewer_invalidations_than_fgl() {
    let b = sized_benchmark(BenchKind::KvAdd, 0.5, cfg().llc.size_bytes, 9);
    let cc = b.run(Variant::CCache, cfg());
    let fgl = b.run(Variant::Fgl, cfg());
    assert!(
        cc.stats.invalidations * 10 < fgl.stats.invalidations.max(10),
        "ccache invalidations {} vs fgl {}",
        cc.stats.invalidations,
        fgl.stats.invalidations
    );
}

#[test]
fn memory_footprint_ordering_matches_table3() {
    // FGL > DUP > CCache for the KV store (Table 3: 12x / 8x / 1x)
    let b = sized_benchmark(BenchKind::KvAdd, 0.5, cfg().llc.size_bytes, 9);
    let fgl = b.run(Variant::Fgl, cfg()).stats.bytes_allocated;
    let dup = b.run(Variant::Dup, cfg()).stats.bytes_allocated;
    let cc = b.run(Variant::CCache, cfg()).stats.bytes_allocated;
    assert!(fgl > dup, "FGL {fgl} <= DUP {dup}");
    assert!(dup > cc, "DUP {dup} <= CCache {cc}");
    let f = fgl as f64 / cc as f64;
    assert!(f > 5.0 && f < 20.0, "FGL ratio {f}");
}

#[test]
fn merge_on_evict_reduces_kmeans_evictions_dramatically() {
    // Fig 9's key datapoint
    let b = sized_benchmark(BenchKind::KMeans, 0.25, cfg().llc.size_bytes, 9);
    let with = b.run(Variant::CCache, cfg());
    let mut no = cfg();
    no.ccache.merge_on_evict = false;
    let without = b.run(Variant::CCache, no);
    assert!(
        without.stats.src_buf_evictions > with.stats.src_buf_evictions.max(1) * 50,
        "no-opt {} vs opt {}",
        without.stats.src_buf_evictions,
        with.stats.src_buf_evictions
    );
}

#[test]
fn dirty_merge_cuts_pagerank_merges() {
    // Section 6.4: PageRank reads much CData it never updates
    let b = sized_benchmark(
        BenchKind::PageRank(GraphKind::Uniform),
        0.5,
        cfg().llc.size_bytes,
        9,
    );
    let with = b.run(Variant::CCache, cfg());
    let mut no = cfg();
    no.ccache.dirty_merge = false;
    let without = b.run(Variant::CCache, no);
    assert!(
        without.stats.merges >= with.stats.merges,
        "dirty-merge increased merges?!"
    );
}

#[test]
fn deterministic_stats_across_runs() {
    let b = sized_benchmark(BenchKind::KvAdd, 0.25, cfg().llc.size_bytes, 5);
    let a = b.run(Variant::CCache, cfg());
    let c = b.run(Variant::CCache, cfg());
    assert_eq!(a.cycles(), c.cycles());
    assert_eq!(a.stats.merges, c.stats.merges);
    assert_eq!(a.stats.llc.misses, c.stats.llc.misses);
}
