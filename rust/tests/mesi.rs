//! Direct MESI-transition coverage through the protocol engine: upgrade,
//! remote fetch from a dirty owner, RFO, and eviction of shared lines —
//! transitions that were previously only covered indirectly via golden
//! runs. Each test asserts the directory state and coherence-event
//! counters, on the 3-level Table 2 shape and the 2-level variant where
//! the shape changes who must notify the directory.

use ccache::sim::addr::Addr;
use ccache::sim::config::MachineConfig;
use ccache::sim::directory::DirState;
use ccache::sim::memsys::MemSystem;

fn sys3(cores: usize) -> MemSystem {
    MemSystem::new(MachineConfig::test_small().with_cores(cores)).unwrap()
}

fn sys2(cores: usize) -> MemSystem {
    MemSystem::new(MachineConfig::test_small_2level().with_cores(cores)).unwrap()
}

#[test]
fn upgrade_invalidates_every_sharer_and_takes_ownership() {
    let mut s = sys3(4);
    let a = s.alloc_lines(64);
    for core in 0..4 {
        s.read(core, a).unwrap();
    }
    let inv_before = s.stats.invalidations;
    let c = s.write(0, a, 1).unwrap();
    // L1 hit + one LLC-class directory round trip for the upgrade
    assert_eq!(c, 4 + 70);
    assert_eq!(s.stats.invalidations, inv_before + 3, "three sharers invalidated");
    assert_eq!(
        s.directory().entry(a.line()).unwrap().state,
        DirState::Owned { owner: 0 }
    );
    s.check_invariants().unwrap();
}

#[test]
fn remote_fetch_from_dirty_owner_pays_forwarding_round_trip() {
    // 3-level: cold write 4+10+70+300; remote read then forwards from the
    // dirty owner: 4+10+70 plus one extra LLC round trip
    let mut s = sys3(2);
    let a = s.alloc_lines(64);
    let c_w = s.write(0, a, 9).unwrap();
    assert_eq!(c_w, 4 + 10 + 70 + 300);
    let wb_before = s.stats.writebacks;
    let (v, c_r) = s.read(1, a).unwrap();
    assert_eq!(v, 9);
    assert_eq!(c_r, 4 + 10 + 70 + 70);
    assert_eq!(s.stats.writebacks, wb_before + 1, "owner forwarded dirty data");
    assert_eq!(s.directory().entry(a.line()).unwrap().state, DirState::Shared);

    // 2-level: same transition without the L2 latency
    let mut s = sys2(2);
    let a = s.alloc_lines(64);
    assert_eq!(s.write(0, a, 9).unwrap(), 4 + 70 + 300);
    let (_, c_r) = s.read(1, a).unwrap();
    assert_eq!(c_r, 4 + 70 + 70);
}

#[test]
fn rfo_steals_the_line_from_a_dirty_owner() {
    let mut s = sys3(2);
    let a = s.alloc_lines(64);
    s.write(0, a, 1).unwrap(); // core 0 owns M
    let inv_before = s.stats.invalidations;
    let wb_before = s.stats.writebacks;
    let c = s.write(1, a, 2).unwrap(); // RFO: invalidate + fetch from owner
    assert_eq!(c, 4 + 10 + 70 + 70);
    assert_eq!(s.stats.invalidations, inv_before + 1);
    assert_eq!(s.stats.writebacks, wb_before + 1);
    assert_eq!(
        s.directory().entry(a.line()).unwrap().state,
        DirState::Owned { owner: 1 }
    );
    // core 0's copy is dead: the next read misses
    let misses = s.stats.l1().misses;
    let (v, _) = s.read(0, a).unwrap();
    assert_eq!(v, 2);
    assert_eq!(s.stats.l1().misses, misses + 1);
    s.check_invariants().unwrap();
}

#[test]
fn evicting_a_shared_line_releases_the_registration_3_level() {
    // fill one L2 set past associativity so the oldest line is evicted
    // from the outermost private level: the eviction must issue a PutS
    // and deregister the core.
    let mut s = sys3(2);
    let l2_sets = s.cfg.level(1).sets() as u64;
    let l2_ways = s.cfg.level(1).ways as u64;
    let base = s.alloc_lines(64 * l2_sets * (l2_ways + 2));
    let stride = l2_sets * 64; // same L2 set every `stride` bytes
    let addrs: Vec<Addr> = (0..=l2_ways).map(|i| Addr(base.0 + i * stride)).collect();
    for &a in &addrs {
        s.read(0, a).unwrap();
    }
    // the first line no longer lists core 0 as a sharer
    let first = addrs[0].line();
    let deregistered = s
        .directory()
        .entry(first)
        .map_or(true, |e| !e.is_sharer(0));
    assert!(deregistered, "PutS did not deregister the evicted sharer");
    // and a write from the other core needs no invalidations for it
    let inv_before = s.stats.invalidations;
    s.write(1, addrs[0], 5).unwrap();
    assert_eq!(s.stats.invalidations, inv_before);
    s.check_invariants().unwrap();
}

#[test]
fn evicting_a_shared_line_releases_the_registration_2_level() {
    // in the 2-level shape the L1 *is* the outermost private level, so
    // an L1 eviction must notify the directory (the 3-level machine's L2
    // would otherwise keep the registration alive)
    let mut s = sys2(2);
    let l1_sets = s.cfg.l1().sets() as u64;
    let l1_ways = s.cfg.l1().ways as u64;
    let base = s.alloc_lines(64 * l1_sets * (l1_ways + 2));
    let stride = l1_sets * 64;
    let addrs: Vec<Addr> = (0..=l1_ways).map(|i| Addr(base.0 + i * stride)).collect();
    for &a in &addrs {
        s.read(0, a).unwrap();
    }
    let first = addrs[0].line();
    let deregistered = s
        .directory()
        .entry(first)
        .map_or(true, |e| !e.is_sharer(0));
    assert!(deregistered, "2-level L1 eviction must issue the put");
    let inv_before = s.stats.invalidations;
    s.write(1, addrs[0], 5).unwrap();
    assert_eq!(s.stats.invalidations, inv_before);
    s.check_invariants().unwrap();
}

#[test]
fn dirty_eviction_writes_back_through_the_hierarchy() {
    let mut s = sys2(1);
    let l1_sets = s.cfg.l1().sets() as u64;
    let l1_ways = s.cfg.l1().ways as u64;
    let base = s.alloc_lines(64 * l1_sets * (l1_ways + 2));
    let stride = l1_sets * 64;
    s.write(0, Addr(base.0), 77).unwrap(); // dirty in L1
    let wb_before = s.stats.writebacks;
    for i in 1..=l1_ways {
        s.read(0, Addr(base.0 + i * stride)).unwrap(); // force the dirty line out
    }
    assert!(s.stats.writebacks > wb_before, "dirty eviction must write back");
    // the data survives: it was always authoritative in flat memory, but
    // the protocol state must still be consistent
    let (v, _) = s.read(0, Addr(base.0)).unwrap();
    assert_eq!(v, 77);
    s.check_invariants().unwrap();
}
