//! Coherence-transition coverage through the composed engine: upgrade,
//! remote fetch from a dirty owner, RFO, and eviction of shared lines —
//! transitions that were previously only covered indirectly via golden
//! runs. Each test asserts the directory state and coherence-event
//! counters, on the 3-level Table 2 shape and the 2-level variant where
//! the shape changes who must notify the directory.
//!
//! The MESI tests pin the baseline; the protocol-parametric and
//! Dragon/partial-coherence sections exercise the same walk under the
//! other [`ProtocolKind`]s — write-update broadcasts instead of
//! invalidations, and a non-coherent shared level where remote stores
//! stay invisible until published.

use ccache::sim::addr::Addr;
use ccache::sim::config::MachineConfig;
use ccache::sim::directory::DirState;
use ccache::sim::hierarchy::ProtocolKind;
use ccache::sim::memsys::MemSystem;

fn sys3(cores: usize) -> MemSystem {
    MemSystem::new(MachineConfig::test_small().with_cores(cores)).unwrap()
}

fn sys2(cores: usize) -> MemSystem {
    MemSystem::new(MachineConfig::test_small_2level().with_cores(cores)).unwrap()
}

fn sys3_proto(cores: usize, p: ProtocolKind) -> MemSystem {
    MemSystem::new(
        MachineConfig::test_small()
            .with_cores(cores)
            .with_protocol(p),
    )
    .unwrap()
}

#[test]
fn upgrade_invalidates_every_sharer_and_takes_ownership() {
    let mut s = sys3(4);
    let a = s.alloc_lines(64);
    for core in 0..4 {
        s.read(core, a).unwrap();
    }
    let inv_before = s.stats.invalidations;
    let c = s.write(0, a, 1).unwrap();
    // L1 hit + one LLC-class directory round trip for the upgrade
    assert_eq!(c, 4 + 70);
    assert_eq!(s.stats.invalidations, inv_before + 3, "three sharers invalidated");
    assert_eq!(
        s.directory().entry(a.line()).unwrap().state,
        DirState::Owned { owner: 0 }
    );
    s.check_invariants().unwrap();
}

#[test]
fn remote_fetch_from_dirty_owner_pays_forwarding_round_trip() {
    // 3-level: cold write 4+10+70+300; remote read then forwards from the
    // dirty owner: 4+10+70 plus one extra LLC round trip
    let mut s = sys3(2);
    let a = s.alloc_lines(64);
    let c_w = s.write(0, a, 9).unwrap();
    assert_eq!(c_w, 4 + 10 + 70 + 300);
    let wb_before = s.stats.writebacks;
    let (v, c_r) = s.read(1, a).unwrap();
    assert_eq!(v, 9);
    assert_eq!(c_r, 4 + 10 + 70 + 70);
    assert_eq!(s.stats.writebacks, wb_before + 1, "owner forwarded dirty data");
    assert_eq!(s.directory().entry(a.line()).unwrap().state, DirState::Shared);

    // 2-level: same transition without the L2 latency
    let mut s = sys2(2);
    let a = s.alloc_lines(64);
    assert_eq!(s.write(0, a, 9).unwrap(), 4 + 70 + 300);
    let (_, c_r) = s.read(1, a).unwrap();
    assert_eq!(c_r, 4 + 70 + 70);
}

#[test]
fn rfo_steals_the_line_from_a_dirty_owner() {
    let mut s = sys3(2);
    let a = s.alloc_lines(64);
    s.write(0, a, 1).unwrap(); // core 0 owns M
    let inv_before = s.stats.invalidations;
    let wb_before = s.stats.writebacks;
    let c = s.write(1, a, 2).unwrap(); // RFO: invalidate + fetch from owner
    assert_eq!(c, 4 + 10 + 70 + 70);
    assert_eq!(s.stats.invalidations, inv_before + 1);
    assert_eq!(s.stats.writebacks, wb_before + 1);
    assert_eq!(
        s.directory().entry(a.line()).unwrap().state,
        DirState::Owned { owner: 1 }
    );
    // core 0's copy is dead: the next read misses
    let misses = s.stats.l1().misses;
    let (v, _) = s.read(0, a).unwrap();
    assert_eq!(v, 2);
    assert_eq!(s.stats.l1().misses, misses + 1);
    s.check_invariants().unwrap();
}

#[test]
fn evicting_a_shared_line_releases_the_registration_3_level() {
    // fill one L2 set past associativity so the oldest line is evicted
    // from the outermost private level: the eviction must issue a PutS
    // and deregister the core.
    let mut s = sys3(2);
    let l2_sets = s.cfg.level(1).sets() as u64;
    let l2_ways = s.cfg.level(1).ways as u64;
    let base = s.alloc_lines(64 * l2_sets * (l2_ways + 2));
    let stride = l2_sets * 64; // same L2 set every `stride` bytes
    let addrs: Vec<Addr> = (0..=l2_ways).map(|i| Addr(base.0 + i * stride)).collect();
    for &a in &addrs {
        s.read(0, a).unwrap();
    }
    // the first line no longer lists core 0 as a sharer
    let first = addrs[0].line();
    let deregistered = s
        .directory()
        .entry(first)
        .map_or(true, |e| !e.is_sharer(0));
    assert!(deregistered, "PutS did not deregister the evicted sharer");
    // and a write from the other core needs no invalidations for it
    let inv_before = s.stats.invalidations;
    s.write(1, addrs[0], 5).unwrap();
    assert_eq!(s.stats.invalidations, inv_before);
    s.check_invariants().unwrap();
}

#[test]
fn evicting_a_shared_line_releases_the_registration_2_level() {
    // in the 2-level shape the L1 *is* the outermost private level, so
    // an L1 eviction must notify the directory (the 3-level machine's L2
    // would otherwise keep the registration alive)
    let mut s = sys2(2);
    let l1_sets = s.cfg.l1().sets() as u64;
    let l1_ways = s.cfg.l1().ways as u64;
    let base = s.alloc_lines(64 * l1_sets * (l1_ways + 2));
    let stride = l1_sets * 64;
    let addrs: Vec<Addr> = (0..=l1_ways).map(|i| Addr(base.0 + i * stride)).collect();
    for &a in &addrs {
        s.read(0, a).unwrap();
    }
    let first = addrs[0].line();
    let deregistered = s
        .directory()
        .entry(first)
        .map_or(true, |e| !e.is_sharer(0));
    assert!(deregistered, "2-level L1 eviction must issue the put");
    let inv_before = s.stats.invalidations;
    s.write(1, addrs[0], 5).unwrap();
    assert_eq!(s.stats.invalidations, inv_before);
    s.check_invariants().unwrap();
}

#[test]
fn dirty_eviction_writes_back_through_the_hierarchy() {
    let mut s = sys2(1);
    let l1_sets = s.cfg.l1().sets() as u64;
    let l1_ways = s.cfg.l1().ways as u64;
    let base = s.alloc_lines(64 * l1_sets * (l1_ways + 2));
    let stride = l1_sets * 64;
    s.write(0, Addr(base.0), 77).unwrap(); // dirty in L1
    let wb_before = s.stats.writebacks;
    for i in 1..=l1_ways {
        s.read(0, Addr(base.0 + i * stride)).unwrap(); // force the dirty line out
    }
    assert!(s.stats.writebacks > wb_before, "dirty eviction must write back");
    // the data survives: it was always authoritative in flat memory, but
    // the protocol state must still be consistent
    let (v, _) = s.read(0, Addr(base.0)).unwrap();
    assert_eq!(v, 77);
    s.check_invariants().unwrap();
}

// ---------------------------------------------------------------------
// protocol-parametric: the same walk under every registered protocol
// ---------------------------------------------------------------------

#[test]
fn single_core_streams_are_identical_across_mesi_and_dragon() {
    // with no second sharer anywhere, write-update degenerates to
    // write-invalidate: every transaction takes the owner==core or
    // Uncached arm in both protocols
    let mut per_proto = Vec::new();
    for p in [ProtocolKind::Mesi, ProtocolKind::Dragon] {
        let mut s = sys3_proto(1, p);
        let a = s.alloc_lines(64 * 16);
        let mut cycles = 0u64;
        for i in 0..64u64 {
            let addr = Addr(a.0 + (i % 16) * 64);
            cycles += s.write(0, addr, i as u32).unwrap();
            let (v, c) = s.read(0, addr).unwrap();
            assert_eq!(v, i as u32);
            cycles += c;
        }
        assert_eq!(s.stats.dragon_updates, 0, "{p}: no sharer, no broadcast");
        per_proto.push((cycles, s.stats.directory_msgs, s.stats.invalidations));
        s.check_invariants().unwrap();
    }
    assert_eq!(per_proto[0], per_proto[1]);
}

#[test]
fn sharing_traffic_distinguishes_every_protocol() {
    // one producer, one consumer, same line: MESI ping-pongs
    // (invalidate + refetch), Dragon broadcasts into retained copies,
    // partial coherence goes fully private — three different bills
    let mut totals = Vec::new();
    for p in ProtocolKind::ALL {
        let mut s = sys3_proto(2, p);
        let a = s.alloc_lines(64);
        let mut cycles = 0u64;
        for i in 0..8 {
            cycles += s.write(0, a, i).unwrap();
            cycles += s.read(1, a).unwrap().1;
        }
        s.check_invariants().unwrap();
        totals.push(cycles);
    }
    assert_ne!(totals[0], totals[1], "dragon must not cost like mesi here");
    assert_ne!(totals[0], totals[2], "partial must not cost like mesi here");
}

#[test]
fn eviction_releases_the_registration_under_both_invalidate_and_update() {
    // the sys3 eviction scenario, parametric: a leaked sharer bit would
    // inflate MESI invalidations and Dragon update fan-out alike
    for p in [ProtocolKind::Mesi, ProtocolKind::Dragon] {
        let mut s = sys3_proto(2, p);
        let l2_sets = s.cfg.level(1).sets() as u64;
        let l2_ways = s.cfg.level(1).ways as u64;
        let base = s.alloc_lines(64 * l2_sets * (l2_ways + 2));
        let stride = l2_sets * 64;
        let addrs: Vec<Addr> = (0..=l2_ways).map(|i| Addr(base.0 + i * stride)).collect();
        for &a in &addrs {
            s.read(0, a).unwrap();
        }
        let deregistered = s
            .directory()
            .entry(addrs[0].line())
            .map_or(true, |e| !e.is_sharer(0));
        assert!(deregistered, "{p}: eviction did not deregister the sharer");
        let inv_before = s.stats.invalidations;
        let upd_before = s.stats.dragon_updates;
        s.write(1, addrs[0], 5).unwrap();
        assert_eq!(s.stats.invalidations, inv_before, "{p}: stale sharer invalidated");
        assert_eq!(s.stats.dragon_updates, upd_before, "{p}: stale sharer updated");
        s.check_invariants().unwrap();
    }
}

// ---------------------------------------------------------------------
// Dragon: write-update through the composed engine
// ---------------------------------------------------------------------

#[test]
fn dragon_write_broadcasts_instead_of_invalidating() {
    let mut s = sys3_proto(4, ProtocolKind::Dragon);
    let a = s.alloc_lines(64);
    for core in 0..4 {
        s.read(core, a).unwrap();
    }
    let inv_before = s.stats.invalidations;
    let c = s.write(0, a, 1).unwrap();
    // L1 hit + one directory round trip + one update message per sharer
    assert_eq!(c, 4 + 70 + 3 * 10);
    assert_eq!(s.stats.invalidations, inv_before, "write-update never invalidates");
    assert_eq!(s.stats.dragon_updates, 1);
    assert_eq!(s.stats.update_words, 3);
    // every sharer kept its copy: the remote read is an L1 hit and sees
    // the broadcast value
    let misses = s.stats.l1().misses;
    let (v, c_r) = s.read(1, a).unwrap();
    assert_eq!((v, c_r), (1, 4));
    assert_eq!(s.stats.l1().misses, misses);
    let e = s.directory().entry(a.line()).unwrap();
    assert_eq!(e.state, DirState::Shared);
    assert_eq!(e.sharer_count(), 4);
    // and the producer pays the broadcast again on its next write
    s.write(0, a, 2).unwrap();
    assert_eq!(s.stats.dragon_updates, 2);
    assert_eq!(s.stats.update_words, 6);
    s.check_invariants().unwrap();
}

#[test]
fn dragon_write_steal_updates_the_old_owner_instead_of_dropping_it() {
    let mut s = sys3_proto(2, ProtocolKind::Dragon);
    let a = s.alloc_lines(64);
    assert_eq!(s.write(0, a, 9).unwrap(), 4 + 10 + 70 + 300); // cold, like MESI
    let inv_before = s.stats.invalidations;
    let c = s.write(1, a, 5).unwrap();
    // walk misses both private levels, forwards from the owner, then
    // pays one update message into the owner's retained copy
    assert_eq!(c, 4 + 10 + 70 + 70 + 10);
    assert_eq!(s.stats.invalidations, inv_before);
    assert_eq!(s.stats.dragon_updates, 1);
    let e = s.directory().entry(a.line()).unwrap();
    assert_eq!(e.state, DirState::Shared);
    assert!(e.is_sharer(0) && e.is_sharer(1), "old owner stays a sharer");
    // the old owner still reads its (updated) copy as an L1 hit
    let (v, c_r) = s.read(0, a).unwrap();
    assert_eq!((v, c_r), (5, 4));
    s.check_invariants().unwrap();
}

#[test]
fn dragon_read_from_dirty_owner_leaves_writeback_with_the_owner() {
    // MESI cleans the owner through on the forward (writeback counted);
    // Dragon's Sm keeps writeback responsibility with the last writer
    let mut s = sys3_proto(2, ProtocolKind::Dragon);
    let a = s.alloc_lines(64);
    s.write(0, a, 9).unwrap();
    let wb_before = s.stats.writebacks;
    let (v, c_r) = s.read(1, a).unwrap();
    assert_eq!(v, 9);
    assert_eq!(c_r, 4 + 10 + 70 + 70, "forwarding round trip like MESI");
    assert_eq!(s.stats.writebacks, wb_before, "Sm: no clean-through on the fetch");
    assert_eq!(s.directory().entry(a.line()).unwrap().state, DirState::Shared);
    s.check_invariants().unwrap();
}

// ---------------------------------------------------------------------
// partial coherence: remote stores are invisible until published
// ---------------------------------------------------------------------

#[test]
fn partial_remote_write_is_invisible_until_merge() {
    let mut s = sys3_proto(2, ProtocolKind::Partial);
    let a = s.alloc_lines(64);
    s.write(0, a, 42).unwrap();
    let (v0, _) = s.read(0, a).unwrap();
    assert_eq!(v0, 42, "the writer reads through its own store buffer");
    let (v1, _) = s.read(1, a).unwrap();
    assert_eq!(v1, 0, "non-coherent: the remote store has not been published");
    // no transaction ever touched the directory
    assert_eq!(s.stats.directory_msgs, 0);
    assert_eq!(s.stats.invalidations, 0);
    assert!(s.directory().is_empty());
    s.check_invariants().unwrap();
    // publishing (what a barrier or merge does) makes it visible
    s.publish_partial(0);
    let (v1, c) = s.read(1, a).unwrap();
    assert_eq!(v1, 42, "published store must be visible");
    assert_eq!(c, 4, "the reader's copy never went anywhere");
}

#[test]
fn partial_private_hits_pay_no_coherence_at_all() {
    let mut s = sys3_proto(2, ProtocolKind::Partial);
    let a = s.alloc_lines(64);
    s.read(0, a).unwrap();
    s.read(1, a).unwrap();
    // both cores hold the line "exclusively"; writes are pure L1 hits
    for i in 0..4 {
        assert_eq!(s.write(0, a, i).unwrap(), 4);
        assert_eq!(s.write(1, a, 100 + i).unwrap(), 4);
    }
    assert_eq!(s.stats.directory_msgs, 0);
    assert_eq!(s.stats.dragon_updates, 0);
    s.check_invariants().unwrap();
}

// ---------------------------------------------------------------------
// engine invariant 8: injected sharer-set corruption is caught
// ---------------------------------------------------------------------

#[test]
fn stale_sharer_bit_injection_is_caught_by_the_engine_invariant() {
    let mut s = sys3(2);
    let a = s.alloc_lines(64);
    s.read(0, a).unwrap();
    s.check_invariants().unwrap();
    // leak a registration for core 1, which holds no copy — exactly what
    // a drop_coherent/eviction bookkeeping bug would leave behind
    let e = s.hierarchy_mut().directory_mut().entry_mut(a.line()).unwrap();
    e.state = DirState::Shared;
    e.sharers |= 0b10;
    let err = s.check_invariants().unwrap_err();
    assert!(err.to_string().contains("stale sharer bit"), "{err}");
}

#[test]
fn partial_coherence_directory_entries_are_caught_by_the_invariant() {
    let mut s = sys3_proto(1, ProtocolKind::Partial);
    let a = s.alloc_lines(64);
    s.read(0, a).unwrap();
    s.check_invariants().unwrap();
    // a non-coherent protocol must never populate the directory
    s.hierarchy_mut().directory_mut().entry_or_insert(a.line());
    assert!(s.check_invariants().is_err());
}
