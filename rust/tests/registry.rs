//! Registry-driven coverage: every registered benchmark x every
//! supported variant runs through the one generic driver and verifies
//! against its sequential golden run — replacing the per-workload copies
//! of this loop that each benchmark used to hand-roll. Unsupported
//! variants must surface as typed errors, never panics.
//!
//! The matrix runs on two distinct hierarchy shapes — the 3-level
//! Table 2 machine and a 2-level (L1 + shared LLC) variant — so shape
//! is exercised as a first-class configuration axis, with golden
//! verification intact on both.

use ccache::exec::registry::{self, SizeSpec};
use ccache::exec::{ExecError, Variant};
use ccache::sim::config::MachineConfig;

const ALL_VARIANTS: [Variant; 5] = Variant::ALL;

fn cfg() -> MachineConfig {
    MachineConfig::test_small().with_cores(2)
}

/// The hierarchy shapes the matrix runs on.
fn shapes() -> Vec<(&'static str, MachineConfig)> {
    vec![
        ("3-level", cfg()),
        ("2-level", MachineConfig::test_small_2level().with_cores(2)),
    ]
}

/// Small but non-trivial instances: 25% of a 64 KiB "LLC".
fn size() -> SizeSpec {
    SizeSpec::new(0.25, 1 << 16, 3)
}

#[test]
fn every_registered_benchmark_verifies_on_every_supported_variant() {
    for (shape, shape_cfg) in shapes() {
        for spec in registry::registry() {
            let bench = spec.build(&size());
            for &v in bench.supported_variants() {
                let r = bench
                    .run(v, shape_cfg.clone())
                    .unwrap_or_else(|e| panic!("{} [{shape}]: {e}", spec.name));
                assert!(
                    r.verified,
                    "{}/{} [{shape}] diverged from the sequential golden run",
                    spec.name,
                    v.name()
                );
                assert_eq!(r.variant, v);
                assert!(
                    r.cycles() > 0,
                    "{}/{} [{shape}]: no cycles",
                    spec.name,
                    v.name()
                );
                assert_eq!(
                    r.stats.depth(),
                    shape_cfg.depth(),
                    "stats must follow the configured hierarchy depth"
                );
            }
        }
    }
}

#[test]
fn unsupported_variants_surface_typed_errors() {
    for spec in registry::registry() {
        let bench = spec.build(&SizeSpec::new(0.05, 1 << 16, 3));
        for v in ALL_VARIANTS {
            if bench.supports(v) {
                continue;
            }
            match bench.run(v, cfg()) {
                Err(ExecError::UnsupportedVariant {
                    benchmark,
                    variant,
                    supported,
                }) => {
                    assert_eq!(variant, v);
                    assert_eq!(benchmark, bench.name());
                    assert!(!supported.is_empty());
                }
                Ok(_) => panic!(
                    "{}: variant {} ran despite not being advertised",
                    spec.name,
                    v.name()
                ),
                Err(e) => panic!("{}: wrong error kind: {e}", spec.name),
            }
        }
    }
}

#[test]
fn histogram_runs_all_five_variants_through_the_driver() {
    let bench = registry::build("histogram", &size()).unwrap();
    assert_eq!(bench.supported_variants().len(), 5);
    for v in ALL_VARIANTS {
        let r = bench.run(v, cfg()).unwrap();
        assert!(r.verified, "histogram/{} diverged", v.name());
    }
}

#[test]
fn invalid_config_surfaces_as_typed_exec_error() {
    let bench = registry::build("kvstore", &size()).unwrap();
    let mut bad = cfg();
    bad.l1_mut().size_bytes = 1000; // geometry broken
    match bench.run(Variant::CCache, bad) {
        Err(ExecError::InvalidConfig(_)) => {}
        other => panic!("expected InvalidConfig, got {other:?}"),
    }
}

#[test]
fn zipf_spec_flows_into_key_distributions() {
    for name in ["kvstore", "histogram"] {
        let bench = registry::build(name, &size().with_zipf(0.9)).unwrap();
        let r = bench.run(Variant::CCache, cfg()).unwrap();
        assert!(r.verified, "{name} with zipf skew diverged");
    }
}

#[test]
fn lookup_resolves_aliases_and_rejects_unknown_names() {
    assert_eq!(registry::lookup("kv").unwrap().name, "kvstore");
    assert_eq!(registry::lookup("bfs").unwrap().name, "bfs-rmat");
    assert_eq!(registry::lookup("hist").unwrap().name, "histogram");
    let err = registry::build("no-such-bench", &size()).unwrap_err();
    assert!(matches!(err, ExecError::UnknownBenchmark { .. }));
    assert!(err.to_string().contains("histogram"), "error lists known names");
}

#[test]
fn results_are_deterministic_across_identical_runs() {
    let bench = registry::build("histogram", &size()).unwrap();
    let a = bench.run(Variant::CCache, cfg()).unwrap();
    let b = bench.run(Variant::CCache, cfg()).unwrap();
    assert_eq!(a.cycles(), b.cycles());
    assert_eq!(a.stats.merges, b.stats.merges);
}
