//! Protocol-engine behaviour through the public `MemSystem` API: the
//! Table 2 timing model, the CCache privatization path, and the merge
//! optimizations (moved out of `sim/memsys.rs` when the module was split
//! into the `sim/hierarchy` stack; the behaviour under test on the
//! 3-level machine is unchanged, and the 2-level shape is covered
//! alongside).

use ccache::merge::funcs::{AddU32, ApproxAddF32};
use ccache::merge::handle;
use ccache::sim::addr::Addr;
use ccache::sim::config::MachineConfig;
use ccache::sim::memsys::MemSystem;

fn sys() -> MemSystem {
    MemSystem::new(MachineConfig::test_small()).unwrap()
}

fn sys2() -> MemSystem {
    MemSystem::new(MachineConfig::test_small_2level()).unwrap()
}

#[test]
fn read_miss_then_hit_latencies() {
    let mut s = sys();
    let a = s.alloc_lines(64);
    // cold: L1(4) + L2(10) + LLC(70) + mem(300)
    let (_, c1) = s.read(0, a).unwrap();
    assert_eq!(c1, 4 + 10 + 70 + 300);
    // hot: L1 hit
    let (_, c2) = s.read(0, a).unwrap();
    assert_eq!(c2, 4);
    // the hot read took the fast path; its hit count sits in the
    // per-core scratch counters until a phase boundary folds it in
    s.flush_hot_stats();
    assert_eq!(s.stats.l1().hits, 1);
    assert_eq!(s.stats.llc().misses, 1);
}

#[test]
fn two_level_read_skips_the_middle_latency() {
    let mut s = sys2();
    let a = s.alloc_lines(64);
    // cold: L1(4) + LLC(70) + mem(300) — no L2 in the stack
    let (_, c1) = s.read(0, a).unwrap();
    assert_eq!(c1, 4 + 70 + 300);
    let (_, c2) = s.read(0, a).unwrap();
    assert_eq!(c2, 4);
    assert_eq!(s.stats.levels.len(), 2);
}

#[test]
fn write_read_roundtrip() {
    let mut s = sys();
    let a = s.alloc_lines(64);
    s.write(0, a, 42).unwrap();
    let (v, _) = s.read(0, a).unwrap();
    assert_eq!(v, 42);
    let (v, _) = s.read(1, a.add(0)).unwrap();
    assert_eq!(v, 42);
}

#[test]
fn write_invalidates_readers() {
    for mut s in [sys(), sys2()] {
        let a = s.alloc_lines(64);
        s.read(0, a).unwrap();
        s.read(1, a).unwrap();
        let inv_before = s.stats.invalidations;
        s.write(0, a, 7).unwrap();
        assert!(s.stats.invalidations > inv_before);
        // core 1 must now miss in L1
        let l1_misses = s.stats.l1().misses;
        s.read(1, a).unwrap();
        assert_eq!(s.stats.l1().misses, l1_misses + 1);
        s.check_invariants().unwrap();
    }
}

#[test]
fn silent_upgrade_on_exclusive() {
    let mut s = sys();
    let a = s.alloc_lines(64);
    s.read(0, a).unwrap(); // granted E (only reader)
    let msgs = s.stats.directory_msgs;
    let c = s.write(0, a, 1).unwrap(); // silent E->M, L1 hit, owned
    assert_eq!(c, 4);
    assert_eq!(s.stats.directory_msgs, msgs);
}

#[test]
fn shared_write_pays_upgrade() {
    let mut s = sys();
    let a = s.alloc_lines(64);
    s.read(0, a).unwrap();
    s.read(1, a).unwrap(); // both sharers now
    let c = s.write(0, a, 1).unwrap(); // L1 hit + upgrade round trip
    assert_eq!(c, 4 + 70);
}

#[test]
fn cas_swaps_and_fails_correctly() {
    let mut s = sys();
    let a = s.alloc_lines(64);
    s.poke(a, 0);
    let (ok, _) = s.cas(0, a, 0, 1).unwrap();
    assert!(ok);
    let (ok, _) = s.cas(1, a, 0, 1).unwrap();
    assert!(!ok);
    assert_eq!(s.peek(a), 1);
}

#[test]
fn cop_privatizes_and_merges_adds() {
    for mut s in [sys(), sys2()] {
        let a = s.alloc_lines(64);
        s.poke(a, 100);
        for core in 0..2 {
            s.merge_init(core, 0, handle(AddU32));
        }
        // both cores increment the same word privately
        let (v0, _) = s.c_read(0, a, 0).unwrap();
        s.c_write(0, a, v0 + 1, 0).unwrap();
        let (v1, _) = s.c_read(1, a, 0).unwrap();
        s.c_write(1, a, v1 + 1, 0).unwrap();
        assert_eq!(v0, 100);
        assert_eq!(v1, 100); // private copies, no interference
        assert_eq!(s.peek(a), 100); // memory untouched before merges
        s.merge_all(0).unwrap();
        assert_eq!(s.peek(a), 101);
        s.merge_all(1).unwrap();
        assert_eq!(s.peek(a), 102); // serialization of both updates
        assert_eq!(s.stats.merges, 2);
        s.check_invariants().unwrap();
    }
}

#[test]
fn cop_generates_no_coherence_traffic() {
    for mut s in [sys(), sys2()] {
        let a = s.alloc_lines(64);
        s.merge_init(0, 0, handle(AddU32));
        s.merge_init(1, 0, handle(AddU32));
        let msgs = s.stats.directory_msgs;
        let invs = s.stats.invalidations;
        for _ in 0..10 {
            let (v, _) = s.c_read(0, a, 0).unwrap();
            s.c_write(0, a, v + 1, 0).unwrap();
            let (v, _) = s.c_read(1, a, 0).unwrap();
            s.c_write(1, a, v + 1, 0).unwrap();
        }
        assert_eq!(s.stats.directory_msgs, msgs, "COps must not touch the directory");
        assert_eq!(s.stats.invalidations, invs);
    }
}

#[test]
fn source_buffer_capacity_forces_merge() {
    let mut s = sys();
    s.merge_init(0, 0, handle(AddU32));
    let cap = s.cfg.ccache.source_buffer_entries;
    let base = s.alloc_lines(64 * (cap as u64 + 1));
    // touch cap+1 distinct lines; mark mergeable so L1 pressure is legal
    for i in 0..=cap as u64 {
        let addr = base.add(i * 64);
        let (v, _) = s.c_read(0, addr, 0).unwrap();
        s.c_write(0, addr, v + 1, 0).unwrap();
        s.soft_merge(0).unwrap();
    }
    assert!(s.stats.src_buf_evictions >= 1);
    assert!(s.stats.merges >= 1);
    s.check_invariants().unwrap();
}

#[test]
fn dirty_merge_drops_clean_lines() {
    let mut s = sys();
    s.merge_init(0, 0, handle(AddU32));
    let a = s.alloc_lines(64);
    s.poke(a, 5);
    s.c_read(0, a, 0).unwrap(); // read-only privatization
    s.merge_all(0).unwrap();
    assert_eq!(s.stats.silent_drops, 1);
    assert_eq!(s.stats.merges, 0);
    assert_eq!(s.peek(a), 5);
}

#[test]
fn no_dirty_merge_merges_clean_lines_too() {
    let mut cfg = MachineConfig::test_small();
    cfg.ccache.dirty_merge = false;
    let mut s = MemSystem::new(cfg).unwrap();
    s.merge_init(0, 0, handle(AddU32));
    let a = s.alloc_lines(64);
    s.c_read(0, a, 0).unwrap();
    s.merge_all(0).unwrap();
    assert_eq!(s.stats.silent_drops, 0);
    assert_eq!(s.stats.merges, 1);
}

#[test]
fn soft_merge_without_opt_flushes() {
    let mut cfg = MachineConfig::test_small();
    cfg.ccache.merge_on_evict = false;
    let mut s = MemSystem::new(cfg).unwrap();
    s.merge_init(0, 0, handle(AddU32));
    let a = s.alloc_lines(64);
    let (v, _) = s.c_read(0, a, 0).unwrap();
    s.c_write(0, a, v + 3, 0).unwrap();
    s.soft_merge(0).unwrap();
    assert_eq!(s.peek(a), 3);
    assert_eq!(s.stats.src_buf_evictions, 1);
    assert!(s.source_buffer(0).is_empty());
}

#[test]
fn soft_merge_with_opt_defers() {
    let mut s = sys();
    s.merge_init(0, 0, handle(AddU32));
    let a = s.alloc_lines(64);
    let (v, _) = s.c_read(0, a, 0).unwrap();
    s.c_write(0, a, v + 3, 0).unwrap();
    s.soft_merge(0).unwrap();
    assert_eq!(s.peek(a), 0, "merge deferred");
    assert!(!s.source_buffer(0).is_empty());
    // re-access resets the mergeable bit
    let (v, _) = s.c_read(0, a, 0).unwrap();
    assert_eq!(v, 3);
    s.merge_all(0).unwrap();
    assert_eq!(s.peek(a), 3);
}

#[test]
fn empty_soft_merge_is_free() {
    // regression: a soft_merge with nothing privatized used to charge
    // marked.max(1) = 1 cycle; a no-op must cost 0 in both policy paths
    let mut s = sys();
    s.merge_init(0, 0, handle(AddU32));
    assert_eq!(s.soft_merge(0).unwrap(), 0, "deferred path");
    let mut cfg = MachineConfig::test_small();
    cfg.ccache.merge_on_evict = false;
    let mut s = MemSystem::new(cfg).unwrap();
    s.merge_init(0, 0, handle(AddU32));
    assert_eq!(s.soft_merge(0).unwrap(), 0, "flush path");
    // a non-empty soft_merge still charges at least one cycle
    let mut s = sys();
    s.merge_init(0, 0, handle(AddU32));
    let a = s.alloc_lines(64);
    let (v, _) = s.c_read(0, a, 0).unwrap();
    s.c_write(0, a, v + 1, 0).unwrap();
    assert!(s.soft_merge(0).unwrap() >= 1);
}

#[test]
#[should_panic(expected = "w-1 rule")]
fn pinned_cdata_overflow_deadlocks() {
    let mut cfg = MachineConfig::test_small();
    cfg.ccache.source_buffer_entries = 64; // don't trip SB capacity first
    let mut s = MemSystem::new(cfg).unwrap();
    s.merge_init(0, 0, handle(AddU32));
    // L1 test_small: 1KB, 4 ways, 4 sets; fill one set with 5 pinned lines
    let sets = s.cfg.l1().sets() as u64;
    let base = s.alloc_lines(64 * sets * 8);
    for i in 0..5u64 {
        let addr = Addr(base.0 + i * sets * 64); // same set
        s.c_read(0, addr, 0).unwrap(); // never soft_merged -> pinned
    }
}

#[test]
fn approx_merge_drops_some_updates() {
    let mut cfg = MachineConfig::test_small();
    cfg.ccache.dirty_merge = true;
    let mut s = MemSystem::new(cfg).unwrap();
    s.merge_init(0, 0, handle(ApproxAddF32 { drop_p: 0.5 }));
    let base = s.alloc_lines(64 * 64);
    for i in 0..64u64 {
        let a = base.add(i * 64);
        let (v, _) = s.c_read(0, a, 0).unwrap();
        s.c_write(0, a, (f32::from_bits(v) + 1.0).to_bits(), 0).unwrap();
        s.merge_all(0).unwrap();
    }
    assert!(s.stats.approx_drops > 5, "drops: {}", s.stats.approx_drops);
    assert!(s.stats.approx_drops < 60);
    // memory reflects kept updates only
    let kept: f32 = (0..64u64).map(|i| s.peek_f32(base.add(i * 64))).sum();
    assert_eq!(kept as u64, 64 - s.stats.approx_drops);
}

#[test]
fn merge_log_records_when_enabled() {
    let mut s = sys();
    s.record_merges = true;
    s.merge_init(0, 0, handle(AddU32));
    let a = s.alloc_lines(64);
    let (v, _) = s.c_read(0, a, 0).unwrap();
    s.c_write(0, a, v + 1, 0).unwrap();
    s.merge_all(0).unwrap();
    assert_eq!(s.merge_log.len(), 1);
    assert_eq!(s.merge_log[0].merge.name(), "add_u32");
    assert_eq!(s.merge_log[0].item.upd[0], 1);
}

#[test]
fn alloc_tracks_footprint_and_aligns() {
    let mut s = sys();
    let a = s.alloc(100, 64);
    assert_eq!(a.0 % 64, 0);
    let b = s.alloc_lines(100);
    assert_eq!(b.0 % 64, 0);
    assert!(b.0 >= a.0 + 100);
    assert_eq!(s.stats.bytes_allocated, 100 + 128);
}
