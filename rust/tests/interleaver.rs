//! The deterministic laggard-first interleaver, exercised through the
//! public `Machine` / `CoreCtx` API (moved out of `sim/machine.rs` when
//! the module was split; the behaviour under test is unchanged).

use ccache::merge::funcs::AddU32;
use ccache::merge::handle;
use ccache::sim::config::MachineConfig;
use ccache::sim::machine::{CoreCtx, Machine};

fn machine() -> Machine {
    Machine::new(MachineConfig::test_small()).unwrap()
}

#[test]
fn single_core_reads_writes() {
    let m = Machine::new(MachineConfig::test_small().with_cores(1)).unwrap();
    let a = m.setup(|mem| mem.alloc_lines(64));
    let stats = m.run(vec![Box::new(move |ctx: &mut CoreCtx| {
        ctx.write_u32(a, 5);
        let v = ctx.read_u32(a);
        assert_eq!(v, 5);
        ctx.compute(10);
    })]);
    assert!(stats.total_cycles() > 10);
}

#[test]
fn two_cores_interleave_deterministically() {
    let run_once = || {
        let m = machine();
        let a = m.setup(|mem| mem.alloc_lines(64));
        let stats = m.run(vec![
            Box::new(move |ctx: &mut CoreCtx| {
                for _ in 0..100 {
                    ctx.read_u32(a);
                    ctx.compute(3);
                }
            }),
            Box::new(move |ctx: &mut CoreCtx| {
                for _ in 0..100 {
                    ctx.read_u32(a.add(64));
                    ctx.compute(7);
                }
            }),
        ]);
        (stats.total_cycles(), stats.l1().hits, stats.directory_msgs)
    };
    assert_eq!(run_once(), run_once());
}

#[test]
fn lock_serializes_increments() {
    let m = machine();
    let (lock, data) = m.setup(|mem| (mem.alloc_lines(64), mem.alloc_lines(64)));
    let n = 200u32;
    let mk = |_id: usize| -> Box<dyn FnOnce(&mut CoreCtx) + Send + '_> {
        Box::new(move |ctx: &mut CoreCtx| {
            for _ in 0..n {
                ctx.lock(lock);
                let v = ctx.read_u32(data);
                ctx.write_u32(data, v + 1);
                ctx.unlock(lock);
            }
        })
    };
    let stats = m.run(vec![mk(0), mk(1)]);
    let total = m.setup(|mem| mem.peek(data));
    assert_eq!(total, 2 * n, "lost updates under lock");
    assert_eq!(stats.lock_acquires, 2 * n as u64);
}

#[test]
fn unsynchronized_ccache_increments_merge_correctly() {
    let m = machine();
    let a = m.setup(|mem| {
        let a = mem.alloc_lines(64);
        mem.poke(a, 1000);
        a
    });
    let n = 50u32;
    let mk = |_| -> Box<dyn FnOnce(&mut CoreCtx) + Send + '_> {
        Box::new(move |ctx: &mut CoreCtx| {
            ctx.merge_init(0, handle(AddU32));
            for _ in 0..n {
                let v = ctx.c_read_u32(a, 0);
                ctx.c_write_u32(a, v + 1, 0);
            }
            ctx.merge();
        })
    };
    m.run(vec![mk(0), mk(1)]);
    let v = m.setup(|mem| mem.peek(a));
    assert_eq!(v, 1000 + 2 * n);
}

#[test]
fn barrier_synchronizes_clocks() {
    let m = machine();
    let a = m.setup(|mem| mem.alloc_lines(128));
    let stats = m.run(vec![
        Box::new(move |ctx: &mut CoreCtx| {
            ctx.compute(10_000); // slow phase 1
            ctx.barrier();
            ctx.write_u32(a, ctx.core_id() as u32 + 1);
        }),
        Box::new(move |ctx: &mut CoreCtx| {
            ctx.compute(10); // fast phase 1
            ctx.barrier();
            ctx.write_u32(a.add(64), ctx.core_id() as u32 + 1);
        }),
    ]);
    // both cores' final clocks must be >= the barrier sync point
    assert!(stats.core_cycles.iter().all(|&c| c >= 10_000));
    assert_eq!(stats.barriers, 2);
}

#[test]
fn barrier_orders_phases() {
    // phase 1: core 0 writes; phase 2: core 1 reads the value
    let m = machine();
    let a = m.setup(|mem| mem.alloc_lines(64));
    m.run(vec![
        Box::new(move |ctx: &mut CoreCtx| {
            ctx.write_u32(a, 77);
            ctx.barrier();
        }),
        Box::new(move |ctx: &mut CoreCtx| {
            ctx.barrier();
            assert_eq!(ctx.read_u32(a), 77);
        }),
    ]);
}

#[test]
fn merge_boundary_pattern_makes_data_visible() {
    // the paper's merge boundary: merge + barrier, then read
    let m = machine();
    let a = m.setup(|mem| mem.alloc_lines(64));
    m.run(vec![
        Box::new(move |ctx: &mut CoreCtx| {
            ctx.merge_init(0, handle(AddU32));
            let v = ctx.c_read_u32(a, 0);
            ctx.c_write_u32(a, v + 5, 0);
            ctx.merge();
            ctx.barrier();
        }),
        Box::new(move |ctx: &mut CoreCtx| {
            ctx.merge_init(0, handle(AddU32));
            let v = ctx.c_read_u32(a, 0);
            ctx.c_write_u32(a, v + 7, 0);
            ctx.merge();
            ctx.barrier();
            assert_eq!(ctx.read_u32(a), 12);
        }),
    ]);
}

#[test]
#[should_panic]
fn core_panic_propagates() {
    let m = machine();
    m.run(vec![
        Box::new(|_ctx: &mut CoreCtx| panic!("boom")),
        Box::new(|ctx: &mut CoreCtx| {
            for _ in 0..1000 {
                ctx.compute(100);
            }
        }),
    ]);
}

#[test]
fn quantum_zero_still_completes() {
    let mut cfg = MachineConfig::test_small();
    cfg.timing.quantum = 0;
    let m = Machine::new(cfg).unwrap();
    let a = m.setup(|mem| mem.alloc_lines(64));
    let stats = m.run(vec![
        Box::new(move |ctx: &mut CoreCtx| {
            for i in 0..50 {
                ctx.write_u32(a, i);
            }
        }),
        Box::new(move |ctx: &mut CoreCtx| {
            for _ in 0..50 {
                ctx.read_u32(a);
            }
        }),
    ]);
    assert!(stats.total_cycles() > 0);
}

#[test]
fn machine_runs_on_a_2_level_hierarchy() {
    let m = Machine::new(MachineConfig::test_small_2level()).unwrap();
    let a = m.setup(|mem| mem.alloc_lines(64));
    let stats = m.run(vec![
        Box::new(move |ctx: &mut CoreCtx| {
            ctx.merge_init(0, handle(AddU32));
            let v = ctx.c_read_u32(a, 0);
            ctx.c_write_u32(a, v + 3, 0);
            ctx.merge();
        }),
        Box::new(move |ctx: &mut CoreCtx| {
            ctx.compute(5);
        }),
    ]);
    assert_eq!(m.setup(|mem| mem.peek(a)), 3);
    assert_eq!(stats.levels.len(), 2, "stats follow the hierarchy depth");
}

#[test]
fn invalid_config_is_rejected_at_machine_construction() {
    let mut cfg = MachineConfig::test_small();
    cfg.llc_mut().size_bytes = 3 << 10; // 3 KiB -> non-power-of-two sets
    assert!(Machine::new(cfg).is_err());
}
