//! The native-thread execution backend, end to end: every workload
//! family the registry ships runs its generic [`Workload`] program on
//! real OS threads (`--backend native`) and lands on the same golden
//! memory image the sequential reference computes — with the coherent
//! variants mapped to real atomics/locks and the privatized variants
//! (dup, ccache) to per-thread buffers merged through the registry's
//! [`MergeFn`](ccache::merge::MergeFn) handles. A merge fault raised on
//! a native thread must surface as the same typed `ExecError` the
//! simulator reports, and the cross-validation grid must agree with the
//! simulator cell for cell.

use ccache::coordinator::{run_xval, XvalOptions};
use ccache::exec::registry::{self, SizeSpec};
use ccache::exec::{driver, Backend, ExecCtx, ExecError, Variant, Workload};
use ccache::merge::{handle, MergeHandle};
use ccache::sim::addr::Addr;
use ccache::sim::config::MachineConfig;
use ccache::sim::memsys::MemSystem;

fn cfg() -> MachineConfig {
    MachineConfig::test_small().with_cores(4)
}

fn build(name: &str) -> ccache::exec::WorkloadHandle {
    let spec = registry::lookup(name).unwrap_or_else(|e| panic!("{e}"));
    spec.build(&SizeSpec::new(0.25, cfg().llc().size_bytes, 9))
}

/// One representative of each of the eight workload families, every
/// variant it supports, on real threads to golden-verified memory.
#[test]
fn all_eight_families_verify_on_native_threads() {
    let families = [
        "kvstore",
        "kmeans",
        "pagerank-uniform",
        "bfs-rmat",
        "histogram",
        "cms",
        "bloom",
        "hll",
    ];
    for name in families {
        let spec = registry::lookup(name).unwrap();
        let bench = build(name);
        for &variant in spec.variants {
            let r = bench
                .run_on(Backend::Native, variant, cfg())
                .unwrap_or_else(|e| panic!("{name}/{}: {e}", variant.name()));
            assert!(
                r.verified,
                "{name}/{} diverged from golden on the native backend",
                variant.name()
            );
            assert!(r.wall_secs.is_some(), "native run lost its wall clock");
            assert!(r.ops_total() > 0, "{name}/{} counted no ops", variant.name());
        }
    }
}

/// The coherent mapping really uses atomics and the privatized mapping
/// really merges: the stats the native machine reports distinguish the
/// two families.
#[test]
fn native_stats_reflect_the_mapping() {
    let bench = build("histogram");
    let atomic = bench.run_on(Backend::Native, Variant::Atomic, cfg()).unwrap();
    assert!(atomic.stats.atomic_rmws > 0, "atomic variant issued no RMWs");
    assert_eq!(atomic.stats.merges, 0);
    let ccache = bench.run_on(Backend::Native, Variant::CCache, cfg()).unwrap();
    assert!(ccache.stats.merges > 0, "ccache variant merged nothing");
    let fgl = bench.run_on(Backend::Native, Variant::Fgl, cfg()).unwrap();
    assert!(fgl.stats.lock_acquires > 0, "fgl variant acquired no locks");
}

/// Minimal workload whose program uses an MFRF slot nothing initialized
/// (the same shape `tests/merge_registry.rs` uses against the sim).
struct BrokenSlotWorkload;

impl Workload for BrokenSlotWorkload {
    type Layout = Addr;
    type Golden = ();

    fn name(&self) -> String {
        "broken-slot".into()
    }

    fn supported_variants(&self) -> Vec<Variant> {
        vec![Variant::CCache]
    }

    fn footprint(&self) -> u64 {
        64
    }

    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        vec![(0, handle(ccache::merge::funcs::AddU32))]
    }

    fn setup(&self, mem: &mut MemSystem, _variant: Variant, _cores: usize) -> Addr {
        mem.alloc_lines(64)
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        _cores: usize,
        _variant: Variant,
        layout: &Addr,
    ) {
        if core == 0 {
            ctx.c_read_u32(*layout, 3); // slot 3 was never merge_init'ed
        } else {
            ctx.compute(10);
        }
    }

    fn golden(&self, _cores: usize) {}

    fn verify(
        &self,
        _mem: &mut MemSystem,
        _layout: &Addr,
        _golden: &(),
        _cores: usize,
    ) -> (bool, Option<f64>) {
        (true, None)
    }
}

/// A merge fault on a native thread is recovered into the same typed
/// error the simulator produces — not a process abort.
#[test]
fn native_merge_fault_is_a_typed_error() {
    let r = driver::run_on(&BrokenSlotWorkload, Backend::Native, Variant::CCache, cfg());
    match r {
        Err(ExecError::MergeFault(fault)) => {
            assert_eq!(fault.core, 0);
            assert_eq!(fault.slot, 3);
        }
        other => panic!("expected MergeFault, got {other:?}"),
    }
}

/// Unsupported variants are rejected before any thread spawns.
#[test]
fn native_backend_rejects_unsupported_variants() {
    let r = driver::run_on(&BrokenSlotWorkload, Backend::Native, Variant::Cgl, cfg());
    assert!(matches!(
        r,
        Err(ExecError::UnsupportedVariant { variant: Variant::Cgl, .. })
    ));
}

/// Cross-validation smoke: a registry subset agrees across backends.
#[test]
fn xval_subset_agrees_across_backends() {
    let report = run_xval(&XvalOptions {
        cores: 2,
        only: vec!["cms".into(), "hll".into()],
        ..Default::default()
    });
    assert_eq!(report.cells.len(), 9); // cms: 5 variants, hll: 4
    assert!(
        report.all_verified(),
        "backend disagreement: {:?}",
        report.failures()
    );
}
