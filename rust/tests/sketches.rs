//! End-to-end coverage of the streaming-sketch workload family through
//! the *registry* path — the same resolution the CLI and the sweeps use:
//! count-min, Bloom and HyperLogLog each run CCache plus the baseline
//! variants, verify against their sequential golden sketches, and flow
//! into `sweep --json` cells. The `max_u8x64` merge function is
//! exercised only through public-API registration (no `merge/` edits).

use ccache::coordinator::report::sweep_json;
use ccache::coordinator::sweep::{run_sweep_with, SweepOptions};
use ccache::exec::registry::{self, SizeSpec, SketchSpec};
use ccache::exec::Variant;
use ccache::merge::default_registry;
use ccache::sim::config::MachineConfig;
use ccache::util::ptest::check_merge_laws;
use ccache::workloads::sketch::register_sketch_merges;

fn cfg() -> MachineConfig {
    MachineConfig::test_small().with_cores(2)
}

/// Small but non-degenerate instances: 12.5% of a 64 KiB "LLC".
fn size() -> SizeSpec {
    SizeSpec::new(0.125, 1 << 16, 9)
}

#[test]
fn sketches_run_ccache_plus_baselines_through_the_registry() {
    // the acceptance floor: ccache + at least two baseline variants per
    // sketch, resolved by registry name, golden-verified
    for name in ["cms", "bloom", "hll"] {
        let bench = registry::build(name, &size()).unwrap();
        let supported = bench.supported_variants();
        assert!(supported.contains(&Variant::CCache), "{name}: no ccache");
        assert!(
            supported.iter().filter(|&&v| v != Variant::CCache).count() >= 2,
            "{name}: fewer than two baseline variants"
        );
        for &v in supported {
            let r = bench.run(v, cfg()).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(
                r.verified,
                "{name}/{} diverged from the sequential golden sketch",
                v.name()
            );
        }
    }
}

#[test]
fn sketch_ccache_cells_name_their_merge_functions() {
    for (name, merge) in [
        ("cms", "sat_add_u32"),
        ("bloom", "bitor"),
        ("hll", "max_u8x64"),
    ] {
        let bench = registry::build(name, &size()).unwrap();
        let r = bench.run(Variant::CCache, cfg()).unwrap();
        assert_eq!(r.merge_fns, vec![merge.to_string()], "{name}");
        assert!(r.stats.merges > 0, "{name}: no merges executed");
        assert!(r.stats.cops > 0, "{name}: no COps executed");
    }
}

#[test]
fn sketches_appear_in_sweep_json_with_the_full_counter_set() {
    let sweep = run_sweep_with(
        "hll",
        &[Variant::Fgl, Variant::CCache],
        &[0.125],
        cfg(),
        SweepOptions {
            seed: 5,
            ..Default::default()
        },
    );
    let j = sweep_json(&sweep, &cfg());
    assert!(j.contains("\"benchmark\": \"hll\""), "{j}");
    assert!(j.contains("\"merge_fns\": [\"max_u8x64\"]"), "{j}");
    for key in [
        "\"ccache_l1_hits\"",
        "\"ccache_fills\"",
        "\"atomic_rmws\"",
        "\"barriers\"",
        "\"approx_drops\"",
    ] {
        assert!(j.contains(key), "sweep cell missing {key}");
    }
}

#[test]
fn sketch_sweeps_cover_the_fraction_axis() {
    for name in ["cms", "bloom"] {
        let sweep = run_sweep_with(
            name,
            &[Variant::Fgl, Variant::Dup, Variant::CCache],
            &[0.125, 0.5],
            cfg(),
            SweepOptions::default(),
        );
        assert_eq!(sweep.points.len(), 2, "{name}");
        for p in &sweep.points {
            assert!(
                p.speedup_vs_fgl(Variant::CCache).unwrap() > 0.0,
                "{name}: missing ccache cell at frac {}",
                p.frac
            );
        }
    }
}

#[test]
fn zipf_skew_flows_into_sketch_streams() {
    for name in ["cms", "bloom", "hll"] {
        let bench = registry::build(name, &size().with_zipf(0.9)).unwrap();
        let r = bench.run(Variant::CCache, cfg()).unwrap();
        assert!(r.verified, "{name} with zipf skew diverged");
    }
}

#[test]
fn sketch_geometry_flows_from_the_size_spec() {
    let spec = size().with_sketch(SketchSpec {
        cms_depth: 2,
        bloom_hashes: 6,
        hll_precision: 7,
    });
    // reshaped instances still verify end to end
    for name in ["cms", "bloom", "hll"] {
        let bench = registry::build(name, &spec).unwrap();
        let r = bench.run(Variant::CCache, cfg()).unwrap();
        assert!(r.verified, "{name} with custom geometry diverged");
    }
}

#[test]
fn hll_reports_estimate_quality() {
    let bench = registry::build("hll", &size()).unwrap();
    let r = bench.run(Variant::CCache, cfg()).unwrap();
    let q = r.quality.expect("hll must report its estimate error");
    assert!((0.0..0.35).contains(&q), "estimate error out of range: {q}");
}

#[test]
fn max_u8x64_registers_via_the_public_api_only_and_passes_the_law_suite() {
    // starting from the stock registry (which does NOT know the sketch
    // functions)...
    let reg = default_registry();
    assert!(
        reg.build("max_u8x64").is_err(),
        "max_u8x64 must not be baked into merge/"
    );
    // ...one public register call makes it resolvable, listable and
    // law-checked like any built-in
    let mut reg = default_registry();
    register_sketch_merges(&mut reg);
    let f = reg.build("max_u8x64").unwrap();
    assert_eq!(f.name(), "max_u8x64");
    assert!(f.idempotent());
    assert!(reg.names().contains(&"max_u8x64".to_string()));
    check_merge_laws(&reg, 0x5E7C, 30);
}
