//! Differential pin for the `CoherenceProtocol` extraction: the MESI
//! state machine that used to be inlined in the hierarchy walk is
//! frozen here as an independent hand-written reference and replayed
//! old-vs-new over seeded streams.
//!
//! Two layers of comparison:
//!
//! * **Lockstep state machine** — the extracted [`Mesi`] trait object
//!   and [`FrozenMesiDir`] (a `HashMap`-based transcription of the
//!   pre-refactor inline directory logic) consume the same random
//!   GetS / GetM / evict / recall stream; every returned
//!   [`CoherenceActions`], every `exclusive` grant, and the full
//!   directory image must match after every single step.
//!
//! * **Engine replay** — seeded coherent read/write streams run
//!   through the real `MemSystem` (fast path on *and* off) and through
//!   a from-scratch reference engine built on the frozen directory
//!   plus true-LRU L1/L2/LLC models. Per-op cycle counts, read values,
//!   the complete [`Stats`] struct, the directory image, and the final
//!   memory words must all be bit-identical. The working set overflows
//!   L1 and L2 but fits the shared level, so the stream exercises
//!   upgrades, downgrades, invalidations, evict transactions and
//!   writebacks without shared-level recalls (those are pinned by the
//!   lockstep layer above).

use std::collections::HashMap;

use ccache::sim::addr::Line;
use ccache::sim::config::MachineConfig;
use ccache::sim::directory::{CoherenceActions, DirState, Directory};
use ccache::sim::hierarchy::ProtocolKind;
use ccache::sim::memsys::MemSystem;
use ccache::sim::stats::{LevelStats, Stats};

// ---------------------------------------------------------------------
// deterministic rng (splitmix64)
// ---------------------------------------------------------------------

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9e37_79b9_7f4a_7c15))
    }

    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

// ---------------------------------------------------------------------
// the frozen pre-refactor MESI directory
// ---------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum FState {
    Uncached,
    Shared,
    Owned(usize),
}

/// Transcription of the directory state machine exactly as it ran when
/// it was inlined in the walk, on the plainest possible storage. Kept
/// deliberately independent of `sim::hierarchy::protocol` — it must
/// not drift along with the code under test.
#[derive(Default)]
struct FrozenMesiDir {
    entries: HashMap<u64, (FState, u64)>,
}

impl FrozenMesiDir {
    fn get_s(&mut self, line: u64, core: usize) -> (CoherenceActions, bool) {
        let e = self.entries.entry(line).or_insert((FState::Uncached, 0));
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        match e.0 {
            FState::Uncached => {
                e.0 = FState::Owned(core);
                e.1 = 1 << core;
            }
            FState::Shared => {
                e.1 |= 1 << core;
            }
            FState::Owned(owner) if owner == core => {}
            FState::Owned(owner) => {
                act.owner_writeback = Some(owner);
                act.dir_msgs += 2;
                e.0 = FState::Shared;
                e.1 |= 1 << core;
            }
        }
        (act, matches!(e.0, FState::Owned(_)))
    }

    fn get_m(&mut self, line: u64, core: usize) -> (CoherenceActions, bool) {
        let e = self.entries.entry(line).or_insert((FState::Uncached, 0));
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        match e.0 {
            FState::Uncached => {}
            FState::Shared => {
                let others = e.1 & !(1u64 << core);
                act.invalidations = others.count_ones();
                act.inv_mask = others;
                act.dir_msgs += act.invalidations;
            }
            FState::Owned(owner) if owner == core => {
                e.1 = 1 << core;
                return (act, true);
            }
            FState::Owned(owner) => {
                act.owner_writeback = Some(owner);
                act.invalidations = 1;
                act.inv_mask = 1 << owner;
                act.dir_msgs += 2;
            }
        }
        e.0 = FState::Owned(core);
        e.1 = 1 << core;
        (act, true)
    }

    fn evict(&mut self, line: u64, core: usize, dirty: bool) -> CoherenceActions {
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        if let Some(e) = self.entries.get_mut(&line) {
            e.1 &= !(1u64 << core);
            match e.0 {
                FState::Owned(owner) if owner == core => {
                    e.0 = if e.1 == 0 {
                        FState::Uncached
                    } else {
                        FState::Shared
                    };
                }
                FState::Shared if e.1 == 0 => {
                    e.0 = FState::Uncached;
                }
                _ => {}
            }
            if dirty {
                act.dir_msgs += 1;
            }
        }
        act
    }

    fn recall(&mut self, line: u64) -> (u64, CoherenceActions) {
        let Some((state, sharers)) = self.entries.remove(&line) else {
            return (0, CoherenceActions::default());
        };
        let act = CoherenceActions {
            invalidations: sharers.count_ones(),
            inv_mask: sharers,
            owner_writeback: match state {
                FState::Owned(owner) => Some(owner),
                _ => None,
            },
            dir_msgs: 1 + sharers.count_ones(),
            ..Default::default()
        };
        (sharers, act)
    }
}

/// The live directory and the frozen model must describe the same
/// lines with the same states and sharer masks — including entries
/// parked at `Uncached`, which both sides retain after an evict.
fn assert_dir_matches(dir: &Directory, frozen: &FrozenMesiDir, ctx: &str) {
    let mut seen = 0usize;
    for (line, e) in dir.iter_entries() {
        let (fs, fsh) = frozen
            .entries
            .get(&line.0)
            .copied()
            .unwrap_or_else(|| panic!("{ctx}: line {:#x} only in the live directory", line.0));
        let want = match fs {
            FState::Uncached => DirState::Uncached,
            FState::Shared => DirState::Shared,
            FState::Owned(owner) => DirState::Owned { owner },
        };
        assert_eq!(e.state, want, "{ctx}: line {:#x} state", line.0);
        assert_eq!(e.sharers, fsh, "{ctx}: line {:#x} sharers", line.0);
        seen += 1;
    }
    assert_eq!(seen, frozen.entries.len(), "{ctx}: entry count");
}

// ---------------------------------------------------------------------
// Part A: lockstep transaction streams, old vs new state machine
// ---------------------------------------------------------------------

#[test]
fn extracted_mesi_replays_identically_to_the_frozen_state_machine() {
    for seed in [1u64, 2, 3, 4, 5] {
        let protocol = ProtocolKind::Mesi.build();
        let mut dir = Directory::new();
        let mut frozen = FrozenMesiDir::default();
        let mut rng = Rng::new(seed);
        // non-vacuity: every interesting action shape must fire
        let (mut fwd, mut invs, mut recalled) = (0u64, 0u64, 0u64);

        for step in 0..2500 {
            let line = Line(rng.below(12) + 1);
            let core = rng.below(4) as usize;
            let ctx = format!("seed {seed} step {step}");
            let (new_act, new_excl, old_act, old_excl) = match rng.below(100) {
                0..=39 => {
                    let g = protocol.read_shared(&mut dir, line, core);
                    let (fa, fe) = frozen.get_s(line.0, core);
                    (g.actions, g.exclusive, fa, fe)
                }
                40..=74 => {
                    let g = protocol.write_shared(&mut dir, line, core);
                    let (fa, fe) = frozen.get_m(line.0, core);
                    (g.actions, g.exclusive, fa, fe)
                }
                75..=91 => {
                    let dirty = rng.below(2) == 1;
                    let a = protocol.evict(&mut dir, line, core, dirty);
                    let fa = frozen.evict(line.0, core, dirty);
                    (a, false, fa, false)
                }
                _ => {
                    let (mask, a) = protocol.recall(&mut dir, line);
                    let (fmask, fa) = frozen.recall(line.0);
                    assert_eq!(mask, fmask, "{ctx}: recall sharer mask");
                    recalled += u64::from(mask != 0);
                    (a, false, fa, false)
                }
            };
            assert_eq!(new_act, old_act, "{ctx}: actions diverged");
            assert_eq!(new_excl, old_excl, "{ctx}: exclusivity diverged");
            // invalidate-based protocol: no update machinery, ever
            assert_eq!(new_act.update_mask, 0, "{ctx}: MESI must not broadcast");
            assert!(!new_act.keep_owner_dirty, "{ctx}: MESI cleans through");
            fwd += u64::from(new_act.owner_writeback.is_some());
            invs += u64::from(new_act.invalidations);
            assert_dir_matches(&dir, &frozen, &ctx);
            dir.check_invariants().unwrap();
        }
        assert!(fwd > 0, "seed {seed}: no owner forward exercised");
        assert!(invs > 0, "seed {seed}: no invalidation exercised");
        assert!(recalled > 0, "seed {seed}: no populated recall exercised");
    }
}

// ---------------------------------------------------------------------
// Part B: full-engine replay against a reference built on the frozen
// directory + true-LRU cache models (test_small geometry)
// ---------------------------------------------------------------------

const H1: u64 = 4; // L1 hit, test_small
const H2: u64 = 10; // L2 hit
const HSH: u64 = 70; // shared-level hit
const HMEM: u64 = 300; // memory

#[derive(Clone, Copy)]
struct RefLine {
    line: u64,
    owned: bool,
    dirty: bool,
    last: u64,
}

/// Set-associative true-LRU array mirroring `sim::cache::Cache` for
/// coherent lines: free ways are taken in way order, otherwise the
/// least-recently-used way is evicted; `probe` never touches recency,
/// `lookup` and `install` do.
struct RefCache {
    sets: usize,
    ways: usize,
    slots: Vec<Option<RefLine>>,
    tick: u64,
}

impl RefCache {
    fn new(sets: usize, ways: usize) -> Self {
        RefCache {
            sets,
            ways,
            slots: vec![None; sets * ways],
            tick: 0,
        }
    }

    fn set_base(&self, line: u64) -> usize {
        (line as usize & (self.sets - 1)) * self.ways
    }

    fn probe(&self, line: u64) -> Option<usize> {
        let base = self.set_base(line);
        (base..base + self.ways).find(|&i| self.slots[i].map_or(false, |l| l.line == line))
    }

    fn lookup(&mut self, line: u64) -> Option<usize> {
        let idx = self.probe(line)?;
        self.tick += 1;
        self.slots[idx].as_mut().unwrap().last = self.tick;
        Some(idx)
    }

    /// First free way in set order, else the LRU way with its metadata.
    fn choose_victim(&self, line: u64) -> (usize, Option<RefLine>) {
        let base = self.set_base(line);
        for i in base..base + self.ways {
            if self.slots[i].is_none() {
                return (i, None);
            }
        }
        let lru = (base..base + self.ways)
            .min_by_key(|&i| self.slots[i].unwrap().last)
            .unwrap();
        (lru, Some(self.slots[lru].unwrap()))
    }

    fn install(&mut self, idx: usize, line: u64, owned: bool, dirty: bool) {
        self.tick += 1;
        self.slots[idx] = Some(RefLine {
            line,
            owned,
            dirty,
            last: self.tick,
        });
    }

    fn invalidate(&mut self, line: u64) -> Option<RefLine> {
        self.probe(line).and_then(|i| self.slots[i].take())
    }

    fn set_flags(&mut self, idx: usize, owned: bool, dirty: bool) {
        let l = self.slots[idx].as_mut().unwrap();
        l.owned = owned;
        l.dirty = dirty;
    }
}

/// Reference engine: the coherent walk's cycle accounting and stat
/// counters re-derived by hand on top of the frozen directory, for the
/// 3-level `test_small` machine. Panics if the stream would force a
/// shared-level eviction (the replay's working set is sized to avoid
/// recalls; Part A pins those).
struct RefEngine {
    l1: Vec<RefCache>,
    l2: Vec<RefCache>,
    llc: RefCache,
    dir: FrozenMesiDir,
    mem: HashMap<usize, u32>,
    l1h: u64,
    l1m: u64,
    l2h: u64,
    l2m: u64,
    shh: u64,
    shm: u64,
    mem_acc: u64,
    dir_msgs: u64,
    invals: u64,
    wbs: u64,
    l2_evicts: u64,
}

impl RefEngine {
    fn new(cores: usize) -> Self {
        RefEngine {
            l1: (0..cores).map(|_| RefCache::new(4, 4)).collect(),
            l2: (0..cores).map(|_| RefCache::new(16, 4)).collect(),
            llc: RefCache::new(32, 8),
            dir: FrozenMesiDir::default(),
            mem: HashMap::new(),
            l1h: 0,
            l1m: 0,
            l2h: 0,
            l2m: 0,
            shh: 0,
            shm: 0,
            mem_acc: 0,
            dir_msgs: 0,
            invals: 0,
            wbs: 0,
            l2_evicts: 0,
        }
    }

    fn apply(&mut self, me: usize, line: u64, act: &CoherenceActions) {
        self.dir_msgs += u64::from(act.dir_msgs);
        self.invals += u64::from(act.invalidations);
        if let Some(owner) = act.owner_writeback {
            if owner != me {
                self.wbs += 1; // MESI always cleans through on a forward
            }
        }
        let mut mask = act.inv_mask;
        while mask != 0 {
            let c = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            if c == me {
                continue;
            }
            self.l1[c].invalidate(line);
            self.l2[c].invalidate(line);
        }
        if act.inv_mask == 0 {
            if let Some(owner) = act.owner_writeback {
                if owner != me {
                    // pure downgrade: copies stay, ownership + dirty clear
                    if let Some(i) = self.l1[owner].probe(line) {
                        self.l1[owner].set_flags(i, false, false);
                    }
                    if let Some(i) = self.l2[owner].probe(line) {
                        self.l2[owner].set_flags(i, false, false);
                    }
                }
            }
        }
    }

    fn upgrade(&mut self, core: usize, line: u64) -> (u64, bool) {
        let (act, exclusive) = self.dir.get_m(line, core);
        let mut cy = HSH;
        if act.owner_writeback.map_or(false, |o| o != core) {
            cy += HSH;
        }
        self.apply(core, line, &act);
        (cy, exclusive)
    }

    fn evict_l2(&mut self, core: usize, victim: RefLine) {
        let mut dirty = victim.dirty;
        if let Some(m) = self.l1[core].invalidate(victim.line) {
            dirty |= m.dirty;
        }
        self.l2[core].invalidate(victim.line);
        let act = self.dir.evict(victim.line, core, dirty);
        self.dir_msgs += u64::from(act.dir_msgs);
        if dirty {
            self.wbs += 1;
            if let Some(i) = self.llc.probe(victim.line) {
                let owned = self.llc.slots[i].unwrap().owned;
                self.llc.set_flags(i, owned, true);
            }
        }
        self.l2_evicts += 1;
    }

    fn fill_l1(&mut self, core: usize, line: u64, owned: bool, dirty: bool) {
        if self.l1[core].probe(line).is_some() {
            return;
        }
        let (way, victim) = self.l1[core].choose_victim(line);
        if let Some(v) = victim {
            // L1 sits below the outermost private level: eviction only
            // writes the dirty bit through to L2, no directory traffic
            self.l1[core].invalidate(v.line);
            if v.dirty {
                if let Some(i) = self.l2[core].probe(v.line) {
                    let o = self.l2[core].slots[i].unwrap().owned;
                    self.l2[core].set_flags(i, o, true);
                }
            }
        }
        self.l1[core].install(way, line, owned, dirty);
    }

    fn fill_l2(&mut self, core: usize, line: u64, owned: bool, dirty: bool) {
        if let Some(i) = self.l2[core].lookup(line) {
            let was_dirty = self.l2[core].slots[i].unwrap().dirty;
            self.l2[core].set_flags(i, owned, was_dirty || dirty);
            return;
        }
        let (way, victim) = self.l2[core].choose_victim(line);
        if let Some(v) = victim {
            self.evict_l2(core, v);
        }
        self.l2[core].install(way, line, owned, dirty);
    }

    fn fetch_shared(&mut self, line: u64) -> bool {
        if self.llc.lookup(line).is_some() {
            self.shh += 1;
            return true;
        }
        self.shm += 1;
        self.mem_acc += 1;
        let (way, victim) = self.llc.choose_victim(line);
        assert!(
            victim.is_none(),
            "reference stream must never evict from the shared level"
        );
        self.llc.install(way, line, false, false);
        false
    }

    fn access(&mut self, core: usize, line: u64, write: bool) -> u64 {
        let mut cy = H1;
        if let Some(idx) = self.l1[core].lookup(line) {
            self.l1h += 1;
            let mut owned = self.l1[core].slots[idx].unwrap().owned;
            if write {
                if !owned {
                    let (up, exclusive) = self.upgrade(core, line);
                    cy += up;
                    owned = exclusive;
                }
                self.l1[core].set_flags(idx, owned, true);
                // the walk refreshes the outer copy with a recency-
                // touching lookup, not a silent probe — mirror that or
                // L2 victim choices drift
                if let Some(i2) = self.l2[core].lookup(line) {
                    self.l2[core].set_flags(i2, owned, true);
                }
            }
            return cy;
        }
        self.l1m += 1;

        cy += H2;
        if let Some(idx) = self.l2[core].lookup(line) {
            self.l2h += 1;
            let mut owned = self.l2[core].slots[idx].unwrap().owned;
            if write {
                if !owned {
                    let (up, exclusive) = self.upgrade(core, line);
                    cy += up;
                    owned = exclusive;
                }
                self.l2[core].set_flags(idx, owned, true);
            }
            self.fill_l1(core, line, owned, write);
            return cy;
        }
        self.l2m += 1;

        cy += HSH;
        let (act, exclusive) = if write {
            self.dir.get_m(line, core)
        } else {
            self.dir.get_s(line, core)
        };
        if act.owner_writeback.map_or(false, |o| o != core) {
            cy += HSH; // forward to the remote owner and wait for data
        }
        self.apply(core, line, &act);
        if !self.fetch_shared(line) {
            cy += HMEM;
        }
        self.fill_l2(core, line, exclusive, write);
        self.fill_l1(core, line, exclusive, write);
        cy
    }
}

/// 80 consecutive lines: overflows each core's L1 (16 lines) and L2
/// (64 lines, 5 mapping to every 4-way set), fits the LLC (at most 3
/// per 8-way set) so no recalls fire.
const NLINES: u64 = 80;
const OPS: u64 = 4000;

fn replay(seed: u64, fast: bool) {
    let mut cfg = MachineConfig::test_small();
    cfg.fast_path = fast;
    let mut s = MemSystem::new(cfg).unwrap();
    let base = s.alloc_lines(NLINES * 64);
    let mut r = RefEngine::new(2);
    let mut rng = Rng::new(seed);
    let ctx = |op: u64| format!("seed {seed} fast {fast} op {op}");

    for op in 0..OPS {
        let core = rng.below(2) as usize;
        let addr = base.add(rng.below(NLINES) * 64 + rng.below(16) * 4);
        let line = addr.line().0;
        if rng.below(100) < 40 {
            let val = rng.next() as u32;
            let cy = s.write(core, addr, val).unwrap();
            let want = r.access(core, line, true);
            r.mem.insert(addr.word_index(), val);
            assert_eq!(cy, want, "{}: write cycles", ctx(op));
        } else {
            let (v, cy) = s.read(core, addr).unwrap();
            let want_cy = r.access(core, line, false);
            let want_v = r.mem.get(&addr.word_index()).copied().unwrap_or(0);
            assert_eq!(v, want_v, "{}: read value", ctx(op));
            assert_eq!(cy, want_cy, "{}: read cycles", ctx(op));
        }
        if op % 500 == 0 {
            s.check_invariants().unwrap();
        }
    }

    s.flush_hot_stats();
    s.check_invariants().unwrap();

    let mut want = Stats::new(2, 3);
    want.levels[0] = LevelStats {
        hits: r.l1h,
        misses: r.l1m,
    };
    want.levels[1] = LevelStats {
        hits: r.l2h,
        misses: r.l2m,
    };
    want.levels[2] = LevelStats {
        hits: r.shh,
        misses: r.shm,
    };
    want.mem_accesses = r.mem_acc;
    want.directory_msgs = r.dir_msgs;
    want.invalidations = r.invals;
    want.writebacks = r.wbs;
    want.bytes_allocated = NLINES * 64;
    assert_eq!(s.stats, want, "seed {seed} fast {fast}: stats diverged");

    assert_dir_matches(
        s.directory(),
        &r.dir,
        &format!("seed {seed} fast {fast} final directory"),
    );

    for li in 0..NLINES {
        for w in 0..16 {
            let a = base.add(li * 64 + w * 4);
            let want = r.mem.get(&a.word_index()).copied().unwrap_or(0);
            assert_eq!(
                s.peek(a),
                want,
                "seed {seed} fast {fast}: memory word line {li} word {w}"
            );
        }
    }

    // non-vacuity: the stream must actually have exercised the paths
    // the refactor moved (misses at every level, the evict transaction,
    // cross-core invalidations, forwards/writebacks)
    assert!(r.l2m > 0 && r.shh > 0, "stream never left the private levels");
    assert!(r.l2_evicts > 0, "stream never fired the evict transaction");
    assert!(r.invals > 0, "stream never invalidated a remote copy");
    assert!(r.wbs > 0, "stream never wrote dirty data back");
}

#[test]
fn engine_replay_matches_the_frozen_reference_with_fast_path_on() {
    for seed in [11u64, 12, 13] {
        replay(seed, true);
    }
}

#[test]
fn engine_replay_matches_the_frozen_reference_with_fast_path_off() {
    for seed in [11u64, 12, 13] {
        replay(seed, false);
    }
}

#[test]
fn cold_read_and_upgrade_latencies_match_the_hand_computed_walk() {
    // spot-check the reference's own arithmetic against first
    // principles, so a bug cancelling out on both sides can't hide:
    // cold read = L1 + L2 + LLC + mem; upgrade from S adds one
    // shared-level round trip; a remote dirty owner adds a second.
    let mut s = MemSystem::new(MachineConfig::test_small()).unwrap();
    let a = s.alloc_lines(64);
    let (_, c) = s.read(0, a).unwrap();
    assert_eq!(c, H1 + H2 + HSH + HMEM);
    let (_, c) = s.read(1, a).unwrap(); // E at core 0: downgrade forward
    assert_eq!(c, H1 + H2 + HSH + HSH);
    let c = s.write(0, a, 7).unwrap(); // S -> M upgrade from an L1 hit
    assert_eq!(c, H1 + HSH);
    let (_, c) = s.read(1, a).unwrap(); // M at core 0: fetch + forward
    assert_eq!(c, H1 + H2 + HSH + HSH);
}
