//! Three-layer round-trip tests: AOT artifacts (JAX/Pallas -> HLO text)
//! loaded and executed from rust via PJRT, cross-checked against the
//! native merge implementations and the simulator's recorded merges.
//!
//! All tests skip gracefully when `make artifacts` hasn't run; the
//! Makefile's `test` target builds artifacts first, so CI-style runs
//! always exercise them.

use ccache::merge::batch::{BatchExecutor, MergeItem, NativeExecutor};
use ccache::merge::funcs::{
    AddF32, AddU32, ApproxAddF32, BitOr, CmulF32, MaxF32, MinF32, SatAddF32,
};
use ccache::merge::{LineData, MergeFn, LINE_WORDS};
use ccache::runtime::artifacts::artifacts_available;
use ccache::runtime::{Engine, PjrtMergeExecutor};
use ccache::util::rng::Rng;

fn rand_items(rng: &mut Rng, n: usize, float: bool) -> Vec<MergeItem> {
    (0..n)
        .map(|_| {
            let mut mk = || {
                let mut l: LineData = [0; LINE_WORDS];
                for w in l.iter_mut() {
                    *w = if float {
                        rng.f32_range(-100.0, 100.0).to_bits()
                    } else {
                        rng.next_u32() >> 8 // keep u32 adds < 2^24 for f32 path
                    };
                }
                l
            };
            MergeItem {
                src: mk(),
                upd: mk(),
                mem: mk(),
                drop_update: rng.bernoulli(0.3),
            }
        })
        .collect()
}

fn close(a: &LineData, b: &LineData, tol: f32) -> bool {
    a.iter().zip(b).all(|(&x, &y)| {
        let (fx, fy) = (f32::from_bits(x), f32::from_bits(y));
        (fx - fy).abs() <= tol * (1.0 + fx.abs().max(fy.abs()))
    })
}

#[test]
fn pjrt_matches_native_for_all_float_kinds() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut pjrt = PjrtMergeExecutor::load_default().unwrap();
    let mut rng = Rng::new(0xF00D);
    let kinds: [&dyn MergeFn; 5] = [
        &AddF32,
        &SatAddF32 { max: 37.0 },
        &MinF32,
        &MaxF32,
        &ApproxAddF32 { drop_p: 0.3 },
    ];
    for kind in kinds {
        // batch sizes exercising padding and chunking
        for n in [1usize, 7, 256, 300, 700] {
            let items = rand_items(&mut rng, n, true);
            let native = NativeExecutor.execute(kind, &items);
            let via = pjrt.execute(kind, &items);
            assert_eq!(native.len(), via.len());
            for (i, (a, b)) in native.iter().zip(&via).enumerate() {
                assert!(
                    close(a, b, 1e-5),
                    "{} n={n} item {i}: native {:?} pjrt {:?}",
                    kind.name(),
                    a[0],
                    b[0]
                );
            }
        }
    }
}

#[test]
fn pjrt_matches_native_cmul() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut pjrt = PjrtMergeExecutor::load_default().unwrap();
    let mut rng = Rng::new(0xCA11);
    let items: Vec<MergeItem> = (0..300)
        .map(|_| {
            let mut mk = |lo: f32, hi: f32| {
                let mut l: LineData = [0; LINE_WORDS];
                for w in l.iter_mut() {
                    *w = rng.f32_range(lo, hi).to_bits();
                }
                l
            };
            MergeItem {
                src: mk(1.0, 4.0), // away from zero
                upd: mk(1.0, 4.0),
                mem: mk(-4.0, 4.0),
                drop_update: false,
            }
        })
        .collect();
    let native = NativeExecutor.execute(&CmulF32, &items);
    let via = pjrt.execute(&CmulF32, &items);
    for (i, (a, b)) in native.iter().zip(&via).enumerate() {
        assert!(close(a, b, 1e-3), "cmul item {i}");
    }
}

#[test]
fn pjrt_matches_native_bitor_exactly() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut pjrt = PjrtMergeExecutor::load_default().unwrap();
    let mut rng = Rng::new(0xB17);
    let items: Vec<MergeItem> = (0..513)
        .map(|_| {
            let mut mk = || {
                let mut l: LineData = [0; LINE_WORDS];
                for w in l.iter_mut() {
                    *w = rng.next_u32() & 0x7FFF_FFFF; // i32-safe lanes
                }
                l
            };
            MergeItem {
                src: mk(),
                upd: mk(),
                mem: mk(),
                drop_update: false,
            }
        })
        .collect();
    let native = NativeExecutor.execute(&BitOr, &items);
    let via = pjrt.execute(&BitOr, &items);
    assert_eq!(native, via, "bitor must be bit-exact");
}

#[test]
fn pjrt_u32_add_exact_below_2_24() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut pjrt = PjrtMergeExecutor::load_default().unwrap();
    let mut rng = Rng::new(0xADD);
    let items: Vec<MergeItem> = (0..256)
        .map(|_| {
            let mut src: LineData = [0; LINE_WORDS];
            let mut mem: LineData = [0; LINE_WORDS];
            for w in src.iter_mut() {
                *w = (rng.next_u32() >> 12) % 1_000_000;
            }
            for w in mem.iter_mut() {
                *w = (rng.next_u32() >> 12) % 1_000_000;
            }
            // ensure upd >= src so the delta is positive (counts)
            let mut upd = src;
            for w in upd.iter_mut() {
                *w += (rng.next_u32() >> 20) % 1000;
            }
            MergeItem {
                src,
                upd,
                mem,
                drop_update: false,
            }
        })
        .collect();
    let native = NativeExecutor.execute(&AddU32, &items);
    let via = pjrt.execute(&AddU32, &items);
    assert_eq!(native, via, "u32 adds below 2^24 must round-trip exactly");
}

#[test]
fn kmeans_step_kernel_matches_host_reference() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut e = Engine::load_default().unwrap();
    let mut rng = Rng::new(0x6E);
    let n = 500;
    let k = 5;
    let points: Vec<[f32; 16]> = (0..n)
        .map(|_| {
            let mut p = [0f32; 16];
            for x in p.iter_mut() {
                *x = rng.f32_range(-10.0, 10.0);
            }
            p
        })
        .collect();
    let centroids: Vec<[f32; 16]> = (0..k)
        .map(|_| {
            let mut c = [0f32; 16];
            for x in c.iter_mut() {
                *x = rng.f32_range(-10.0, 10.0);
            }
            c
        })
        .collect();
    let (assign, sums, counts) = e.kmeans_step(&points, &centroids).unwrap();

    // host reference
    let mut want_assign = vec![0i32; n];
    let mut want_sums = vec![[0f32; 16]; k];
    let mut want_counts = vec![0f32; k];
    for (i, p) in points.iter().enumerate() {
        let mut best = 0;
        let mut bd = f32::INFINITY;
        for (c, cen) in centroids.iter().enumerate() {
            let d: f32 = p.iter().zip(cen).map(|(a, b)| (a - b) * (a - b)).sum();
            if d < bd {
                bd = d;
                best = c;
            }
        }
        want_assign[i] = best as i32;
        for j in 0..16 {
            want_sums[best][j] += p[j];
        }
        want_counts[best] += 1.0;
    }
    assert_eq!(assign, want_assign);
    assert_eq!(counts, want_counts);
    for c in 0..k {
        for j in 0..16 {
            assert!(
                (sums[c][j] - want_sums[c][j]).abs() < 1e-2,
                "sums[{c}][{j}]"
            );
        }
    }
}
