//! Property-based tests (in-house driver, rust/src/util/ptest.rs) on the
//! simulator's coordinator invariants: protocol-state legality, merge
//! serializability, LRU/inclusion behaviour and merge-function algebra.

use ccache::merge::funcs::apply_line;
use ccache::merge::{LineData, MergeKind, LINE_WORDS};
use ccache::sim::addr::{Addr, Line};
use ccache::sim::cache::{Cache, Victim};
use ccache::sim::config::MachineConfig;
use ccache::sim::directory::Directory;
use ccache::sim::memsys::MemSystem;
use ccache::util::ptest::{check, PropResult};
use ccache::util::rng::Rng;

// ---------------------------------------------------------------------
// directory protocol legality under random op sequences
// ---------------------------------------------------------------------

#[test]
fn property_directory_invariants_under_random_traffic() {
    check(
        0xD1,
        100,
        |rng| {
            let n = 20 + rng.usize_below(200);
            (0..n)
                .map(|_| rng.below(4) * 100 + rng.below(4) * 10 + rng.below(8))
                .collect::<Vec<u64>>()
        },
        |ops| -> PropResult {
            let mut d = Directory::new();
            for &op in ops {
                let kind = op / 100;
                let line = Line((op / 10) % 10);
                let core = (op % 10) as usize;
                match kind {
                    0 => {
                        d.get_s(line, core);
                    }
                    1 => {
                        d.get_m(line, core);
                    }
                    2 => {
                        d.put(line, core, core % 2 == 0);
                    }
                    _ => {
                        d.recall(line);
                    }
                }
                d.check_invariants()?;
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// LRU cache: no duplicate tags, bounded occupancy
// ---------------------------------------------------------------------

#[test]
fn property_cache_never_duplicates_tags() {
    check(
        0xCA,
        60,
        |rng| {
            let n = 50 + rng.usize_below(400);
            (0..n).map(|_| rng.below(64)).collect::<Vec<u64>>()
        },
        |lines| -> PropResult {
            let mut c = Cache::new(8, 4);
            for &l in lines {
                let line = Line(l);
                if c.lookup(line).is_some() {
                    continue;
                }
                match c.choose_victim(line) {
                    Victim::Free { way } => {
                        c.install(way, line);
                    }
                    Victim::Evict { way, meta } => {
                        c.invalidate(meta.line);
                        c.install(way, line);
                    }
                    Victim::Deadlock => return Err("deadlock without CData".into()),
                }
                // no duplicate tags
                let mut seen = std::collections::HashSet::new();
                for slot in c.valid_slots() {
                    if !seen.insert(c.meta(slot).line.0) {
                        return Err(format!("duplicate tag {:#x}", c.meta(slot).line.0));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// merge serializability: N cores' commutative updates through the full
// machine equal the sequential sum regardless of interleaving
// ---------------------------------------------------------------------

#[test]
fn property_cop_increments_serialize() {
    check(
        0x5E,
        25,
        |rng| {
            // (lines, increments per core) — both shrinkable
            (1 + rng.usize_below(32), 1 + rng.usize_below(200))
        },
        |&(nlines, incs)| -> PropResult {
            let mut cfg = MachineConfig::test_small();
            cfg.cores = 1;
            let mut s = MemSystem::new(cfg).unwrap();
            s.merge_init(0, 0, MergeKind::AddU32);
            let base = s.alloc_lines(64 * nlines as u64);
            let mut rng = Rng::new(42);
            let mut expected = vec![0u32; nlines];
            for _ in 0..incs {
                let k = rng.usize_below(nlines);
                let a = Addr(base.0 + (k as u64) * 64);
                let (v, _) = s.c_read(0, a, 0);
                s.c_write(0, a, v + 1, 0);
                s.soft_merge(0);
                expected[k] += 1;
            }
            s.merge_all(0);
            s.check_invariants()?;
            for k in 0..nlines {
                let got = s.peek(Addr(base.0 + k as u64 * 64));
                if got != expected[k] {
                    return Err(format!("line {k}: got {got}, want {}", expected[k]));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// merge-function algebra: order independence (the paper's Section 3
// correctness condition) for every registered kind
// ---------------------------------------------------------------------

fn rand_line(rng: &mut Rng, lo: f32, hi: f32) -> LineData {
    let mut l = [0u32; LINE_WORDS];
    for w in l.iter_mut() {
        *w = rng.f32_range(lo, hi).to_bits();
    }
    l
}

#[test]
fn property_merge_kinds_order_independent() {
    let kinds = [
        MergeKind::AddF32,
        MergeKind::MinF32,
        MergeKind::MaxF32,
        MergeKind::BitOr,
        MergeKind::CmulF32,
    ];
    check(
        0xA1,
        40,
        |rng| rng.below(u64::MAX),
        |&seed| -> PropResult {
            let mut rng = Rng::new(seed);
            for kind in kinds {
                let (mem0, src, a, b) = match kind {
                    MergeKind::BitOr => {
                        let mut mk = || {
                            let mut l = [0u32; LINE_WORDS];
                            for w in l.iter_mut() {
                                *w = rng.next_u32();
                            }
                            l
                        };
                        (mk(), [0u32; LINE_WORDS], mk(), mk())
                    }
                    MergeKind::CmulF32 => (
                        rand_line(&mut rng, -2.0, 2.0),
                        rand_line(&mut rng, 1.0, 3.0),
                        rand_line(&mut rng, 1.0, 3.0),
                        rand_line(&mut rng, 1.0, 3.0),
                    ),
                    _ => (
                        rand_line(&mut rng, -100.0, 100.0),
                        rand_line(&mut rng, -100.0, 100.0),
                        rand_line(&mut rng, -100.0, 100.0),
                        rand_line(&mut rng, -100.0, 100.0),
                    ),
                };
                let ab = apply_line(kind, &src, &b, &apply_line(kind, &src, &a, &mem0, false), false);
                let ba = apply_line(kind, &src, &a, &apply_line(kind, &src, &b, &mem0, false), false);
                for i in 0..LINE_WORDS {
                    let (x, y) = (f32::from_bits(ab[i]), f32::from_bits(ba[i]));
                    let exact = matches!(kind, MergeKind::BitOr | MergeKind::MinF32 | MergeKind::MaxF32);
                    let ok = if exact {
                        ab[i] == ba[i]
                    } else {
                        (x - y).abs() <= 1e-3 * (1.0 + x.abs().max(y.abs()))
                    };
                    if !ok {
                        return Err(format!("{kind:?}: lane {i}: {x} vs {y}"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// memsys invariants under random legal COp/coherent phases (multi-core)
// ---------------------------------------------------------------------

#[test]
fn property_memsys_invariants_random_phases() {
    check(
        0x3C,
        15,
        |rng| (rng.below(u64::MAX), 2 + rng.usize_below(3)),
        |&(seed, cores)| -> PropResult {
            let mut cfg = MachineConfig::test_small();
            cfg.cores = cores;
            let mut s = MemSystem::new(cfg).unwrap();
            for c in 0..cores {
                s.merge_init(c, 0, MergeKind::AddU32);
            }
            let cdata = s.alloc_lines(64 * 128);
            let coh = s.alloc_lines(64 * 128);
            let mut rng = Rng::new(seed);
            for _phase in 0..4 {
                for _ in 0..500 {
                    let core = rng.usize_below(cores);
                    let k = rng.below(128);
                    match rng.below(4) {
                        0 | 1 => {
                            let a = Addr(cdata.0 + k * 64);
                            let (v, _) = s.c_read(core, a, 0);
                            s.c_write(core, a, v.wrapping_add(1), 0);
                            s.soft_merge(core);
                        }
                        2 => {
                            let _ = s.read(core, Addr(coh.0 + k * 64));
                        }
                        _ => {
                            s.write(core, Addr(coh.0 + k * 64), k as u32);
                        }
                    }
                }
                for c in 0..cores {
                    s.merge_all(c);
                }
                s.check_invariants()?;
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// failure injection: the w-1 rule faults loudly instead of corrupting
// ---------------------------------------------------------------------

#[test]
fn pinned_overflow_panics_with_w1_message() {
    let result = std::panic::catch_unwind(|| {
        let mut cfg = MachineConfig::test_small();
        cfg.ccache.source_buffer_entries = 64;
        let mut s = MemSystem::new(cfg).unwrap();
        s.merge_init(0, 0, MergeKind::AddU32);
        let sets = s.cfg.l1().sets() as u64;
        let base = s.alloc_lines(64 * sets * 8);
        for i in 0..5u64 {
            // same set, never soft_merged -> pinned
            s.c_read(0, Addr(base.0 + i * sets * 64), 0);
        }
    });
    let msg = match result.unwrap_err().downcast::<String>() {
        Ok(s) => *s,
        Err(p) => *p.downcast::<&str>().map(|s| Box::new(s.to_string())).unwrap(),
    };
    assert!(msg.contains("w-1"), "unexpected panic message: {msg}");
}

#[test]
fn uninitialized_merge_type_faults() {
    let result = std::panic::catch_unwind(|| {
        let mut cfg = MachineConfig::test_small();
        cfg.ccache.dirty_merge = false;
        let mut s = MemSystem::new(cfg).unwrap();
        s.merge_init(0, 0, MergeKind::AddU32);
        let a = s.alloc_lines(64);
        // merge type 2 was never installed
        let (v, _) = s.c_read(0, a, 2);
        s.c_write(0, a, v + 1, 2);
        s.merge_all(0);
    });
    assert!(result.is_err(), "uninitialized MFRF slot must fault");
}
