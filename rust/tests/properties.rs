//! Property-based tests (in-house driver, rust/src/util/ptest.rs) on the
//! simulator's coordinator invariants: protocol-state legality, merge
//! serializability, LRU/inclusion behaviour and merge-function algebra.

use ccache::merge::funcs::AddU32;
use ccache::merge::{default_registry, handle, MergeRegistry};
use ccache::sim::addr::{Addr, Line};
use ccache::sim::cache::{Cache, Victim};
use ccache::sim::config::MachineConfig;
use ccache::sim::directory::Directory;
use ccache::sim::memsys::MemSystem;
use ccache::util::ptest::{check, check_merge_laws, PropResult};
use ccache::util::rng::Rng;

// ---------------------------------------------------------------------
// directory protocol legality under random op sequences
// ---------------------------------------------------------------------

#[test]
fn property_directory_invariants_under_random_traffic() {
    check(
        0xD1,
        100,
        |rng| {
            let n = 20 + rng.usize_below(200);
            (0..n)
                .map(|_| rng.below(4) * 100 + rng.below(4) * 10 + rng.below(8))
                .collect::<Vec<u64>>()
        },
        |ops| -> PropResult {
            let mut d = Directory::new();
            for &op in ops {
                let kind = op / 100;
                let line = Line((op / 10) % 10);
                let core = (op % 10) as usize;
                match kind {
                    0 => {
                        d.get_s(line, core);
                    }
                    1 => {
                        d.get_m(line, core);
                    }
                    2 => {
                        d.put(line, core, core % 2 == 0);
                    }
                    _ => {
                        d.recall(line);
                    }
                }
                d.check_invariants()?;
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// LRU cache: no duplicate tags, bounded occupancy
// ---------------------------------------------------------------------

#[test]
fn property_cache_never_duplicates_tags() {
    check(
        0xCA,
        60,
        |rng| {
            let n = 50 + rng.usize_below(400);
            (0..n).map(|_| rng.below(64)).collect::<Vec<u64>>()
        },
        |lines| -> PropResult {
            let mut c = Cache::new(8, 4);
            for &l in lines {
                let line = Line(l);
                if c.lookup(line).is_some() {
                    continue;
                }
                match c.choose_victim(line) {
                    Victim::Free { way } => {
                        c.install(way, line);
                    }
                    Victim::Evict { way, meta } => {
                        c.invalidate(meta.line);
                        c.install(way, line);
                    }
                    Victim::Deadlock => return Err("deadlock without CData".into()),
                }
                // no duplicate tags
                let mut seen = std::collections::HashSet::new();
                for slot in c.valid_slots() {
                    if !seen.insert(c.meta(slot).line.0) {
                        return Err(format!("duplicate tag {:#x}", c.meta(slot).line.0));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// merge serializability: N cores' commutative updates through the full
// machine equal the sequential sum regardless of interleaving
// ---------------------------------------------------------------------

#[test]
fn property_cop_increments_serialize() {
    check(
        0x5E,
        25,
        |rng| {
            // (lines, increments per core) — both shrinkable
            (1 + rng.usize_below(32), 1 + rng.usize_below(200))
        },
        |&(nlines, incs)| -> PropResult {
            let mut cfg = MachineConfig::test_small();
            cfg.cores = 1;
            let mut s = MemSystem::new(cfg).unwrap();
            s.merge_init(0, 0, handle(AddU32));
            let base = s.alloc_lines(64 * nlines as u64);
            let mut rng = Rng::new(42);
            let mut expected = vec![0u32; nlines];
            for _ in 0..incs {
                let k = rng.usize_below(nlines);
                let a = Addr(base.0 + (k as u64) * 64);
                let (v, _) = s.c_read(0, a, 0).unwrap();
                s.c_write(0, a, v + 1, 0).unwrap();
                s.soft_merge(0).unwrap();
                expected[k] += 1;
            }
            s.merge_all(0).unwrap();
            s.check_invariants()?;
            for k in 0..nlines {
                let got = s.peek(Addr(base.0 + k as u64 * 64));
                if got != expected[k] {
                    return Err(format!("line {k}: got {got}, want {}", expected[k]));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// merge-function algebra: order independence (the paper's Section 3
// correctness condition), auto-generated over the merge registry —
// every registered function (built-in, extension or user-registered)
// is checked without this file naming it
// ---------------------------------------------------------------------

#[test]
fn property_every_registered_merge_obeys_the_laws() {
    check_merge_laws(&default_registry(), 0xA1, 40);
}

#[test]
fn property_sketch_merges_register_publicly_and_obey_the_laws() {
    // the workload-layer max_u8x64 registers through the same public
    // call a downstream crate would use, and the auto-generated suite
    // law-checks it alongside every built-in
    let mut reg = default_registry();
    ccache::workloads::sketch::register_sketch_merges(&mut reg);
    let f = reg.build("max_u8x64").unwrap();
    assert!(f.idempotent());
    check_merge_laws(&reg, 0xA3, 40);
}

#[test]
fn property_user_registered_merge_is_law_checked_for_free() {
    use ccache::merge::{LineData, MergeFn, LINE_WORDS};

    // a brand-new function registered through the public API only
    struct MulF32;
    impl MergeFn for MulF32 {
        fn name(&self) -> &str {
            "mul_f32"
        }
        fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _d: bool) -> LineData {
            let mut out = *mem;
            for i in 0..LINE_WORDS {
                let (s, u, m) = (
                    f32::from_bits(src[i]),
                    f32::from_bits(upd[i]),
                    f32::from_bits(mem[i]),
                );
                out[i] = (m * (u / s)).to_bits();
            }
            out
        }
        fn sample_line(
            &self,
            rng: &mut ccache::util::rng::Rng,
            _role: ccache::merge::MergeOperand,
        ) -> LineData {
            ccache::merge::funcs::f32_line(rng, 1.0, 4.0)
        }
        fn law_tolerance(&self) -> f32 {
            1e-3
        }
    }

    let mut reg = MergeRegistry::with_builtins();
    reg.register("mul_f32", "multiplicative accumulation", |_| Ok(handle(MulF32)));
    check_merge_laws(&reg, 0xA2, 40);
}

// ---------------------------------------------------------------------
// memsys invariants under random legal COp/coherent phases (multi-core)
// ---------------------------------------------------------------------

#[test]
fn property_memsys_invariants_random_phases() {
    check(
        0x3C,
        15,
        |rng| (rng.below(u64::MAX), 2 + rng.usize_below(3)),
        |&(seed, cores)| -> PropResult {
            let mut cfg = MachineConfig::test_small();
            cfg.cores = cores;
            let mut s = MemSystem::new(cfg).unwrap();
            // the same function in two slots: random re-typing between
            // them exercises the rebind path (invariant 5: the L1 meta
            // and the source-buffer binding must stay in lock-step)
            // without perturbing the additive results
            for c in 0..cores {
                s.merge_init(c, 0, handle(AddU32));
                s.merge_init(c, 1, handle(AddU32));
            }
            let cdata = s.alloc_lines(64 * 128);
            let coh = s.alloc_lines(64 * 128);
            let mut rng = Rng::new(seed);
            for _phase in 0..4 {
                for op in 0..500 {
                    let core = rng.usize_below(cores);
                    let k = rng.below(128);
                    match rng.below(4) {
                        0 | 1 => {
                            let ty = rng.below(2) as u8;
                            let a = Addr(cdata.0 + k * 64);
                            let (v, _) = s.c_read(core, a, ty).unwrap();
                            s.c_write(core, a, v.wrapping_add(1), ty).unwrap();
                            s.soft_merge(core).unwrap();
                        }
                        2 => {
                            let _ = s.read(core, Addr(coh.0 + k * 64)).unwrap();
                        }
                        _ => {
                            s.write(core, Addr(coh.0 + k * 64), k as u32).unwrap();
                        }
                    }
                    if op % 100 == 99 {
                        // mid-phase: lines are still privatized here, so
                        // merge-type skew is visible (post-merge it is not)
                        s.check_invariants()?;
                    }
                }
                for c in 0..cores {
                    s.merge_all(c).unwrap();
                }
                s.check_invariants()?;
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// failure injection: the w-1 rule faults loudly instead of corrupting
// ---------------------------------------------------------------------

#[test]
fn pinned_overflow_panics_with_w1_message() {
    let result = std::panic::catch_unwind(|| {
        let mut cfg = MachineConfig::test_small();
        cfg.ccache.source_buffer_entries = 64;
        let mut s = MemSystem::new(cfg).unwrap();
        s.merge_init(0, 0, handle(AddU32));
        let sets = s.cfg.l1().sets() as u64;
        let base = s.alloc_lines(64 * sets * 8);
        for i in 0..5u64 {
            // same set, never soft_merged -> pinned
            s.c_read(0, Addr(base.0 + i * sets * 64), 0).unwrap();
        }
    });
    let msg = match result.unwrap_err().downcast::<String>() {
        Ok(s) => *s,
        Err(p) => *p.downcast::<&str>().map(|s| Box::new(s.to_string())).unwrap(),
    };
    assert!(msg.contains("w-1"), "unexpected panic message: {msg}");
}

#[test]
fn uninitialized_merge_type_is_a_typed_machine_fault() {
    let mut cfg = MachineConfig::test_small();
    cfg.ccache.dirty_merge = false;
    let mut s = MemSystem::new(cfg).unwrap();
    s.merge_init(0, 0, handle(AddU32));
    let a = s.alloc_lines(64);
    // merge type 2 was never installed: the COp traps with a typed
    // fault (no panic, no state corruption)
    let fault = s.c_read(0, a, 2).unwrap_err();
    assert_eq!(fault.core, 0);
    assert_eq!(fault.slot, 2);
    assert!(fault.to_string().contains("merge_init"));
    // the fault is recorded for execution-layer recovery
    let recorded = s.take_fault().expect("fault recorded");
    assert_eq!(recorded, fault);
    // the machine stays usable on the initialized slot
    let (v, _) = s.c_read(0, a, 0).unwrap();
    s.c_write(0, a, v + 1, 0).unwrap();
    s.merge_all(0).unwrap();
    assert_eq!(s.peek(a), 1);
    s.check_invariants().unwrap();
}
