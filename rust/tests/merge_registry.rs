//! The open merge-API contract, end to end:
//!
//! 1. a user-defined [`MergeFn`] registers through the public
//!    [`MergeRegistry`] API, gets law-checked by the auto-generated
//!    property suite, and drives a real workload (kvstore) to golden
//!    verification — with zero edits to the `merge/` module;
//! 2. the nine built-ins resolve by name and produce bit-identical
//!    results to the workload's own merge path;
//! 3. a COp naming an uninitialized MFRF slot surfaces as the typed
//!    `ExecError::MergeFault`, not a panic.
//!
//! CI runs this file, so breaking the extension path fails the build.

use ccache::exec::registry::{self, SizeSpec};
use ccache::exec::{driver, ExecCtx, ExecError, Variant, Workload};
use ccache::merge::{handle, LineData, MergeFn, MergeHandle, MergeRegistry, LINE_WORDS};
use ccache::sim::addr::Addr;
use ccache::sim::config::MachineConfig;
use ccache::sim::memsys::MemSystem;
use ccache::util::ptest::check_merge_laws;

/// A user-supplied merge function: additive (so kvstore's golden
/// verification holds) and observable — it counts how many lines it
/// merged, something the old closed enum could never express.
#[derive(Default)]
struct CountingAddU32 {
    lines_merged: std::sync::atomic::AtomicU64,
}

impl MergeFn for CountingAddU32 {
    fn name(&self) -> &str {
        "counting_add_u32"
    }

    fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        self.lines_merged
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            out[i] = mem[i].wrapping_add(upd[i].wrapping_sub(src[i]));
        }
        out
    }
}

fn cfg() -> MachineConfig {
    MachineConfig::test_small().with_cores(2)
}

fn kv_size() -> SizeSpec {
    SizeSpec::new(0.5, cfg().llc().size_bytes, 11)
}

#[test]
fn user_merge_fn_registers_and_law_checks_through_the_public_api() {
    let mut reg = MergeRegistry::with_builtins();
    reg.register("counting_add_u32", "observable add", |_| {
        Ok(handle(CountingAddU32::default()))
    });
    assert!(reg.names().contains(&"counting_add_u32".to_string()));
    // the whole registry — builtins plus the new function — passes the
    // auto-generated commutativity/idempotence suite
    check_merge_laws(&reg, 0xE0, 30);
}

#[test]
fn user_merge_fn_drives_kvstore_to_golden_verification() {
    let counting = std::sync::Arc::new(CountingAddU32::default());
    let as_handle: MergeHandle = counting.clone();

    let bench = registry::build("kvstore", &kv_size()).unwrap();
    let r = bench
        .run_with_merge(Variant::CCache, cfg(), Some(as_handle))
        .unwrap();
    assert!(r.verified, "user merge function diverged from golden");
    assert_eq!(r.merge_fns, vec!["counting_add_u32".to_string()]);
    // the user function really ran on the merge path
    let merged = counting
        .lines_merged
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(merged > 0, "custom merge function never invoked");
    assert_eq!(merged, r.stats.merges, "one apply per simulator merge");
}

#[test]
fn registry_built_builtin_is_bit_identical_to_the_workload_path() {
    let bench = registry::build("kvstore", &kv_size()).unwrap();
    let native = bench.run(Variant::CCache, cfg()).unwrap();
    let via_registry = bench
        .run_with_merge(
            Variant::CCache,
            cfg(),
            Some(MergeRegistry::with_builtins().build("add_u32").unwrap()),
        )
        .unwrap();
    assert!(native.verified && via_registry.verified);
    assert_eq!(native.cycles(), via_registry.cycles());
    assert_eq!(native.stats.merges, via_registry.stats.merges);
    assert_eq!(native.merge_fns, via_registry.merge_fns);
}

#[test]
fn run_results_carry_the_installed_merge_names() {
    let bench = registry::build("kmeans", &kv_size()).unwrap();
    let cc = bench.run(Variant::CCache, cfg()).unwrap();
    assert_eq!(
        cc.merge_fns,
        vec!["add_f32".to_string(), "add_f32".to_string()],
        "one name per MFRF slot"
    );
    let fgl = bench.run(Variant::Fgl, cfg()).unwrap();
    assert!(fgl.merge_fns.is_empty(), "locks install no merge function");
}

/// Minimal workload whose program uses an MFRF slot nothing initialized.
struct BrokenSlotWorkload;

impl Workload for BrokenSlotWorkload {
    type Layout = Addr;
    type Golden = ();

    fn name(&self) -> String {
        "broken-slot".into()
    }

    fn supported_variants(&self) -> Vec<Variant> {
        vec![Variant::CCache]
    }

    fn footprint(&self) -> u64 {
        64
    }

    // note: installs slot 0 only; the program uses slot 3
    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        vec![(0, handle(ccache::merge::funcs::AddU32))]
    }

    fn setup(&self, mem: &mut MemSystem, _variant: Variant, _cores: usize) -> Addr {
        mem.alloc_lines(64)
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        _cores: usize,
        _variant: Variant,
        layout: &Addr,
    ) {
        if core == 0 {
            ctx.c_read_u32(*layout, 3); // slot 3 was never merge_init'ed
        } else {
            ctx.compute(10);
        }
    }

    fn golden(&self, _cores: usize) {}

    fn verify(
        &self,
        _mem: &mut MemSystem,
        _layout: &Addr,
        _golden: &(),
        _cores: usize,
    ) -> (bool, Option<f64>) {
        (true, None)
    }
}

#[test]
fn uninitialized_slot_surfaces_as_a_typed_exec_error() {
    let r = driver::run(&BrokenSlotWorkload, Variant::CCache, cfg());
    match r {
        Err(ExecError::MergeFault(fault)) => {
            assert_eq!(fault.core, 0);
            assert_eq!(fault.slot, 3);
            let msg = ExecError::MergeFault(fault).to_string();
            assert!(msg.contains("merge fault"), "{msg}");
            assert!(msg.contains("merge_init"), "{msg}");
        }
        other => panic!("expected MergeFault, got {other:?}"),
    }
}
