//! Differential proof that the engine's branch-light fast path
//! (`MachineConfig::fast_path`) is an *exact* shortcut: identical
//! randomized operation streams replayed through a fast-path-on and a
//! fast-path-off engine must produce bit-identical [`Stats`], final
//! memory, and cycle totals — and the same must hold end-to-end through
//! the execution driver for all five workload variants.

use ccache::exec::registry::{self, SizeSpec};
use ccache::exec::Variant;
use ccache::merge::funcs::AddU32;
use ccache::merge::handle;
use ccache::sim::config::MachineConfig;
use ccache::sim::hierarchy::level::PartitionPolicy;
use ccache::sim::hierarchy::ProtocolKind;
use ccache::sim::memsys::MemSystem;
use ccache::sim::stats::Stats;
use ccache::util::ptest::check_diff;
use ccache::util::rng::Rng;

/// Replay a seeded stream of mixed operations — COp read-modify-writes
/// over a CData region, coherent reads/writes/CAS/fetch_or over a
/// disjoint region, and soft merges — through a fresh engine, with a
/// full merge at each of three phase boundaries. Returns everything the
/// fast path could possibly perturb: the final stats, a final-memory
/// snapshot, and the sum of every cycle count the engine handed back.
fn run_stream(seed: u64, cores: usize, fast: bool) -> (Stats, Vec<u32>, u64) {
    let cores = cores.max(1);
    let mut cfg = MachineConfig::test_small();
    cfg.cores = cores;
    cfg.fast_path = fast;
    let mut s = MemSystem::new(cfg).unwrap();
    let cdata = s.alloc_lines(64 * 128);
    let coh = s.alloc_lines(64 * 128);
    for core in 0..cores {
        s.merge_init(core, 0, handle(AddU32));
        s.merge_init(core, 1, handle(AddU32));
    }
    let mut rng = Rng::new(seed);
    let mut cycles = 0u64;
    for _phase in 0..3 {
        for _ in 0..400 {
            let core = rng.usize_below(cores);
            let line = rng.below(128);
            match rng.below(6) {
                0 => {
                    let ty = rng.below(2) as u8;
                    let a = cdata.add(line * 64 + rng.below(16) * 4);
                    let (v, c1) = s.c_read(core, a, ty).unwrap();
                    let c2 = s.c_write(core, a, v.wrapping_add(1), ty).unwrap();
                    cycles += c1 + c2;
                }
                1 => cycles += s.soft_merge(core).unwrap(),
                2 => cycles += s.read(core, coh.add(line * 64)).unwrap().1,
                3 => cycles += s.write(core, coh.add(line * 64), rng.next_u32()).unwrap(),
                4 => {
                    let (_, c) = s.cas(core, coh.add(line * 64), 0, rng.next_u32()).unwrap();
                    cycles += c;
                }
                _ => {
                    let (_, c) = s
                        .fetch_or(core, coh.add(line * 64), rng.next_u32())
                        .unwrap();
                    cycles += c;
                }
            }
        }
        // phase boundary: every core merges its CData
        for core in 0..cores {
            cycles += s.merge_all(core).unwrap();
        }
    }
    s.flush_hot_stats();
    s.check_invariants().unwrap();
    let mut memory = Vec::with_capacity(256);
    for i in 0..128u64 {
        memory.push(s.peek(cdata.add(i * 64)));
    }
    for i in 0..128u64 {
        memory.push(s.peek(coh.add(i * 64)));
    }
    (s.stats.clone(), memory, cycles)
}

#[test]
fn fast_path_is_bit_identical_on_random_streams() {
    check_diff(
        0xFA57,
        10,
        |rng| (rng.below(u64::MAX), 1 + rng.usize_below(2)),
        |&(seed, cores)| run_stream(seed, cores, true),
        |&(seed, cores)| run_stream(seed, cores, false),
    );
}

/// Like [`run_stream`], but under a selectable coherence protocol, with
/// the engine invariants (including invariant 8, the sharer/directory
/// agreement) swept every 100 ops. Partial coherence has no coherent
/// RMWs — the driver typed-rejects variants that need them — so its
/// stream substitutes plain reads/writes for the CAS and fetch_or arms;
/// the invalidate/update protocols replay the full mix.
fn run_protocol_stream(
    seed: u64,
    cores: usize,
    p: ProtocolKind,
    fast: bool,
) -> (Stats, Vec<u32>, u64) {
    let cores = cores.max(1);
    let mut cfg = MachineConfig::test_small().with_protocol(p);
    cfg.cores = cores;
    cfg.fast_path = fast;
    let mut s = MemSystem::new(cfg).unwrap();
    let cdata = s.alloc_lines(64 * 128);
    let coh = s.alloc_lines(64 * 128);
    for core in 0..cores {
        s.merge_init(core, 0, handle(AddU32));
        s.merge_init(core, 1, handle(AddU32));
    }
    let rmw = p.supports("atomic");
    let mut rng = Rng::new(seed);
    let mut cycles = 0u64;
    let mut ops = 0u64;
    for _phase in 0..3 {
        for _ in 0..400 {
            let core = rng.usize_below(cores);
            let line = rng.below(128);
            match rng.below(6) {
                0 => {
                    let ty = rng.below(2) as u8;
                    let a = cdata.add(line * 64 + rng.below(16) * 4);
                    let (v, c1) = s.c_read(core, a, ty).unwrap();
                    let c2 = s.c_write(core, a, v.wrapping_add(1), ty).unwrap();
                    cycles += c1 + c2;
                }
                1 => cycles += s.soft_merge(core).unwrap(),
                2 => cycles += s.read(core, coh.add(line * 64)).unwrap().1,
                3 => cycles += s.write(core, coh.add(line * 64), rng.next_u32()).unwrap(),
                4 if rmw => {
                    let (_, c) = s.cas(core, coh.add(line * 64), 0, rng.next_u32()).unwrap();
                    cycles += c;
                }
                4 => cycles += s.read(core, coh.add(line * 64)).unwrap().1,
                _ if rmw => {
                    let (_, c) = s
                        .fetch_or(core, coh.add(line * 64), rng.next_u32())
                        .unwrap();
                    cycles += c;
                }
                _ => cycles += s.write(core, coh.add(line * 64), rng.next_u32()).unwrap(),
            }
            ops += 1;
            if ops % 100 == 0 {
                s.check_invariants().unwrap();
            }
        }
        // phase boundary: every core merges (which, under partial
        // coherence, also publishes its store buffer)
        for core in 0..cores {
            cycles += s.merge_all(core).unwrap();
        }
    }
    s.flush_hot_stats();
    s.check_invariants().unwrap();
    let mut memory = Vec::with_capacity(256);
    for i in 0..128u64 {
        memory.push(s.peek(cdata.add(i * 64)));
    }
    for i in 0..128u64 {
        memory.push(s.peek(coh.add(i * 64)));
    }
    (s.stats.clone(), memory, cycles)
}

#[test]
fn fast_path_is_bit_identical_under_every_protocol() {
    for (tag, p) in [
        (0xD1F0u64, ProtocolKind::Mesi),
        (0xD1F1, ProtocolKind::Dragon),
        (0xD1F2, ProtocolKind::Partial),
    ] {
        check_diff(
            tag,
            6,
            |rng| (rng.below(u64::MAX), 1 + rng.usize_below(2)),
            |&(seed, cores)| run_protocol_stream(seed, cores, p, true),
            |&(seed, cores)| run_protocol_stream(seed, cores, p, false),
        );
    }
}

/// Non-vacuity pin for the protocol axis above: the replayed streams
/// really exercise each protocol's distinctive machinery, rather than
/// all three degenerating to the same traffic.
#[test]
fn protocol_streams_are_not_vacuous() {
    let (mesi, _, mesi_cyc) = run_protocol_stream(7, 2, ProtocolKind::Mesi, true);
    let (dragon, _, dragon_cyc) = run_protocol_stream(7, 2, ProtocolKind::Dragon, true);
    let (partial, _, partial_cyc) = run_protocol_stream(7, 2, ProtocolKind::Partial, true);
    assert!(dragon.dragon_updates > 0, "stream never hit a write-update");
    assert_eq!(mesi.dragon_updates, 0);
    assert!(mesi.directory_msgs > 0);
    assert_eq!(partial.directory_msgs, 0, "partial coherence sent directory traffic");
    assert_eq!(partial.invalidations, 0);
    assert_ne!(mesi_cyc, dragon_cyc, "dragon timed exactly like mesi");
    assert_ne!(mesi_cyc, partial_cyc, "partial timed exactly like mesi");
}

/// Number of lines that collide in a single L1 set of
/// [`MachineConfig::test_small`] (1 KiB, 4-way, 64 B lines -> 4 sets),
/// deliberately larger than the 4 ways so CData lines continuously
/// evict and the freed ways get reused by *different* CData lines.
const PRESSURE_LINES: u64 = 12;

/// Byte stride that keeps consecutive stream lines in the same set
/// (4 sets x 64 B).
const SET_STRIDE: u64 = 256;

/// Like [`run_stream`], but every CData access lands in one L1 set with
/// a working set 3x the way count: the pure eviction-pressure regime
/// where a stale `cdata_slot` way binding would resolve a COp to the
/// wrong source-buffer slot.
fn run_pressure_stream(seed: u64, cores: usize, fast: bool) -> (Stats, Vec<u32>, u64) {
    let cores = cores.max(1);
    let mut cfg = MachineConfig::test_small();
    cfg.cores = cores;
    cfg.fast_path = fast;
    let mut s = MemSystem::new(cfg).unwrap();
    let cdata = s.alloc_lines(SET_STRIDE * PRESSURE_LINES);
    for core in 0..cores {
        s.merge_init(core, 0, handle(AddU32));
    }
    let mut rng = Rng::new(seed);
    let mut cycles = 0u64;
    for _phase in 0..3 {
        for _ in 0..300 {
            let core = rng.usize_below(cores);
            let a = cdata.add(rng.below(PRESSURE_LINES) * SET_STRIDE);
            match rng.below(4) {
                0 => {
                    let (v, c1) = s.c_read(core, a, 0).unwrap();
                    let c2 = s.c_write(core, a, v.wrapping_add(1), 0).unwrap();
                    cycles += c1 + c2;
                }
                1 => cycles += s.c_write(core, a, rng.next_u32(), 0).unwrap(),
                2 => cycles += s.soft_merge(core).unwrap(),
                _ => cycles += s.c_read(core, a, 0).unwrap().1,
            }
            s.check_invariants().unwrap();
        }
        for core in 0..cores {
            cycles += s.merge_all(core).unwrap();
        }
    }
    s.flush_hot_stats();
    s.check_invariants().unwrap();
    let memory = (0..PRESSURE_LINES)
        .map(|i| s.peek(cdata.add(i * SET_STRIDE)))
        .collect();
    (s.stats.clone(), memory, cycles)
}

#[test]
fn fast_path_is_bit_identical_under_eviction_pressure() {
    check_diff(
        0xE71C,
        8,
        |rng| (rng.below(u64::MAX), 1 + rng.usize_below(2)),
        |&(seed, cores)| run_pressure_stream(seed, cores, true),
        |&(seed, cores)| run_pressure_stream(seed, cores, false),
    );
}

/// Regression for the `cdata_slot` stale-binding hazard: merge a CData
/// line out of a full set, install a *different* CData line into the
/// freed way, and check the COp fast path resolves the new line's
/// source-buffer slot (a stale binding would hand back the evicted
/// line's slot — invariant 6 in `check_invariants` pins this).
fn way_reuse(fast: bool) -> Vec<u32> {
    let mut cfg = MachineConfig::test_small();
    cfg.cores = 1;
    cfg.fast_path = fast;
    let mut s = MemSystem::new(cfg).unwrap();
    let cdata = s.alloc_lines(SET_STRIDE * 5);
    s.merge_init(0, 0, handle(AddU32));
    // fill one 4-way set with four CData lines
    for (i, val) in [10u32, 20, 30, 40].into_iter().enumerate() {
        s.c_write(0, cdata.add(i as u64 * SET_STRIDE), val, 0).unwrap();
    }
    // mark them mergeable so the eviction below merges instead of faulting
    s.soft_merge(0).unwrap();
    // the fifth line forces a CData eviction and reuses the freed way
    let fifth = cdata.add(4 * SET_STRIDE);
    s.c_write(0, fifth, 50, 0).unwrap();
    s.check_invariants().unwrap();
    // the COp must see the new line's slot, not the evicted line's
    let (v, _) = s.c_read(0, fifth, 0).unwrap();
    assert_eq!(v, 50, "fast path resolved a stale cdata_slot binding");
    // the evicted lines re-read their own values (resident or merged)
    for (i, val) in [10u32, 20, 30, 40].into_iter().enumerate() {
        let (v, _) = s.c_read(0, cdata.add(i as u64 * SET_STRIDE), 0).unwrap();
        assert_eq!(v, val, "line {i} lost its update across the way reuse");
    }
    s.merge_all(0).unwrap();
    s.flush_hot_stats();
    s.check_invariants().unwrap();
    (0..5).map(|i| s.peek(cdata.add(i * SET_STRIDE))).collect()
}

#[test]
fn cdata_way_reuse_resolves_the_new_slot() {
    assert_eq!(way_reuse(true), vec![10, 20, 30, 40, 50]);
    assert_eq!(way_reuse(false), vec![10, 20, 30, 40, 50]);
}

/// Mid-phase stats must be readable without flushing: `stats_snapshot`
/// folds the fast path's hot counters non-destructively, so a fast-path
/// engine mid-phase reports exactly what a slow-path engine does — and
/// asking twice changes nothing.
#[test]
fn mid_phase_stats_snapshot_matches_slow_path() {
    let run = |fast: bool| {
        let mut cfg = MachineConfig::test_small();
        cfg.cores = 1;
        cfg.fast_path = fast;
        let mut s = MemSystem::new(cfg).unwrap();
        let cdata = s.alloc_lines(64 * 32);
        let coh = s.alloc_lines(64 * 32);
        s.merge_init(0, 0, handle(AddU32));
        let mut rng = Rng::new(0x57A7);
        for _ in 0..200 {
            let line = rng.below(32);
            match rng.below(3) {
                0 => {
                    let a = cdata.add(line * 64);
                    let (v, _) = s.c_read(0, a, 0).unwrap();
                    s.c_write(0, a, v.wrapping_add(1), 0).unwrap();
                }
                1 => {
                    s.read(0, coh.add(line * 64)).unwrap();
                }
                _ => {
                    s.write(0, coh.add(line * 64), rng.next_u32()).unwrap();
                }
            }
        }
        s
    };
    let fast = run(true);
    let slow = run(false);
    // mid-phase (nothing flushed): the snapshots agree across paths
    let snap_fast = fast.stats_snapshot();
    assert_eq!(snap_fast, slow.stats_snapshot());
    // non-destructive: a second snapshot is identical, and the fold
    // did not drain the hot counters into the base stats
    assert_eq!(fast.stats_snapshot(), snap_fast);
    // the raw (unfolded) fast-path stats really were behind, so the
    // snapshot is load-bearing, not a tautology
    assert!(
        fast.stats.levels[0].hits < snap_fast.levels[0].hits
            || fast.stats.cops < snap_fast.cops,
        "fast path kept no hot counters; snapshot test is vacuous"
    );
    // a destructive flush lands on the same totals
    let mut fast = fast;
    fast.flush_hot_stats();
    assert_eq!(fast.stats, snap_fast);
}

/// Like [`run_stream`], but on an LLC whose merge region is way-
/// partitioned. The coherent region (384 lines) outsizes the ordinary
/// partition of the small LLC (32 sets x 6 non-merge ways = 192 lines)
/// and the CData region (128 lines) outsizes the 2-way merge region
/// (64 lines), so shared-level evictions continuously cross the
/// way-mask boundary in both classes. Under the reuse-aware policy the
/// epoch controller resizes the region mid-stream — the partition
/// invariant is checked every 128 ops, and the fast path must stay
/// bit-identical through every repartition (the controller ticks once
/// per timed access on both paths, so epoch decisions land on the same
/// op indices).
fn run_partitioned_stream(
    seed: u64,
    cores: usize,
    policy: PartitionPolicy,
    fast: bool,
) -> (Stats, Vec<u32>, u64) {
    let cores = cores.max(1);
    let mut cfg = MachineConfig::test_small().with_partition(2, policy);
    cfg.cores = cores;
    cfg.fast_path = fast;
    let mut s = MemSystem::new(cfg).unwrap();
    let cdata = s.alloc_lines(64 * 128);
    let coh = s.alloc_lines(64 * 384);
    for core in 0..cores {
        s.merge_init(core, 0, handle(AddU32));
        s.merge_init(core, 1, handle(AddU32));
    }
    let mut rng = Rng::new(seed);
    let mut cycles = 0u64;
    let mut ops = 0u64;
    for _phase in 0..3 {
        for _ in 0..400 {
            let core = rng.usize_below(cores);
            match rng.below(6) {
                0 => {
                    let ty = rng.below(2) as u8;
                    let a = cdata.add(rng.below(128) * 64 + rng.below(16) * 4);
                    let (v, c1) = s.c_read(core, a, ty).unwrap();
                    let c2 = s.c_write(core, a, v.wrapping_add(1), ty).unwrap();
                    cycles += c1 + c2;
                }
                1 => cycles += s.soft_merge(core).unwrap(),
                2 => cycles += s.read(core, coh.add(rng.below(384) * 64)).unwrap().1,
                3 => {
                    cycles += s
                        .write(core, coh.add(rng.below(384) * 64), rng.next_u32())
                        .unwrap()
                }
                4 => {
                    let (_, c) = s
                        .cas(core, coh.add(rng.below(384) * 64), 0, rng.next_u32())
                        .unwrap();
                    cycles += c;
                }
                _ => {
                    let (_, c) = s
                        .fetch_or(core, coh.add(rng.below(384) * 64), rng.next_u32())
                        .unwrap();
                    cycles += c;
                }
            }
            ops += 1;
            if ops % 128 == 0 {
                // invariant 7 rides along: CData-classed shared lines
                // stay inside the (possibly just-resized) merge region
                s.check_invariants().unwrap();
            }
        }
        for core in 0..cores {
            cycles += s.merge_all(core).unwrap();
        }
    }
    s.flush_hot_stats();
    s.check_invariants().unwrap();
    let mut memory = Vec::with_capacity(512);
    for i in 0..128u64 {
        memory.push(s.peek(cdata.add(i * 64)));
    }
    for i in 0..384u64 {
        memory.push(s.peek(coh.add(i * 64)));
    }
    (s.stats.clone(), memory, cycles)
}

#[test]
fn fast_path_is_bit_identical_on_partitioned_machines() {
    for (tag, policy) in [
        (0x9A27u64, PartitionPolicy::Static),
        (0x9A28, PartitionPolicy::ReuseAware),
    ] {
        check_diff(
            tag,
            6,
            |rng| (rng.below(u64::MAX), 1 + rng.usize_below(2)),
            |&(seed, cores)| run_partitioned_stream(seed, cores, policy, true),
            |&(seed, cores)| run_partitioned_stream(seed, cores, policy, false),
        );
    }
}

/// Non-vacuity pin for the differential test above: a deterministic
/// stream that forces the reuse-aware controller to actually move the
/// boundary. A burst of CData traffic, then a long coherent-only
/// stretch — the first full epoch (512 timed accesses) without CData
/// fills must shrink the merge region, so `repartitions` is provably
/// nonzero on the very streams the bit-identity test replays.
#[test]
fn reuse_controller_repartitions_mid_stream() {
    let mut cfg = MachineConfig::test_small().with_partition(2, PartitionPolicy::ReuseAware);
    cfg.cores = 1;
    let mut s = MemSystem::new(cfg).unwrap();
    // 8 CData lines: resident in the small L1 (16 lines), so the burst
    // never forces an unmergeable eviction
    let cdata = s.alloc_lines(64 * 8);
    let coh = s.alloc_lines(64 * 64);
    s.merge_init(0, 0, handle(AddU32));
    for i in 0..64u64 {
        s.c_write(0, cdata.add((i % 8) * 64), 1, 0).unwrap();
    }
    // > 2 epochs of coherent-only traffic: zero CData fills per epoch
    for i in 0..1200u64 {
        s.read(0, coh.add((i % 64) * 64)).unwrap();
    }
    s.merge_all(0).unwrap();
    s.flush_hot_stats();
    s.check_invariants().unwrap();
    assert!(
        s.stats.repartitions > 0,
        "the reuse-aware controller never resized the merge region"
    );
    assert!(
        s.stats.partition_ways_min < 2,
        "fill-starved epochs should have shrunk the 2-way region (min {})",
        s.stats.partition_ways_min
    );
    assert!(s.stats.partition_ways_final >= 1);
}

/// The same exactness, end-to-end through the execution driver (machine
/// threads, merge-region registration, golden verification) for every
/// workload variant the repo ships: CGL, FGL, DUP, CCache, and BFS's
/// atomic variant.
#[test]
fn five_variants_bit_identical_through_the_driver() {
    let cells = [
        ("kvstore", Variant::Cgl),
        ("kvstore", Variant::Fgl),
        ("kvstore", Variant::Dup),
        ("kvstore", Variant::CCache),
        ("bfs", Variant::Atomic),
    ];
    for (name, variant) in cells {
        let spec = registry::lookup(name).unwrap();
        let bench = spec.build(&SizeSpec::new(0.25, 16 << 10, 7));
        let mut fast_cfg = MachineConfig::test_small();
        fast_cfg.fast_path = true;
        let mut slow_cfg = MachineConfig::test_small();
        slow_cfg.fast_path = false;
        let fast = bench.run_with_merge(variant, fast_cfg, None).unwrap();
        let slow = bench.run_with_merge(variant, slow_cfg, None).unwrap();
        assert!(
            fast.verified && slow.verified,
            "{name}/{} failed golden verification",
            variant.name()
        );
        assert_eq!(
            fast.stats,
            slow.stats,
            "stats diverged for {name}/{}",
            variant.name()
        );
    }
}
