//! Differential proof that the engine's branch-light fast path
//! (`MachineConfig::fast_path`) is an *exact* shortcut: identical
//! randomized operation streams replayed through a fast-path-on and a
//! fast-path-off engine must produce bit-identical [`Stats`], final
//! memory, and cycle totals — and the same must hold end-to-end through
//! the execution driver for all five workload variants.

use ccache::exec::registry::{self, SizeSpec};
use ccache::exec::Variant;
use ccache::merge::funcs::AddU32;
use ccache::merge::handle;
use ccache::sim::config::MachineConfig;
use ccache::sim::memsys::MemSystem;
use ccache::sim::stats::Stats;
use ccache::util::ptest::check_diff;
use ccache::util::rng::Rng;

/// Replay a seeded stream of mixed operations — COp read-modify-writes
/// over a CData region, coherent reads/writes/CAS/fetch_or over a
/// disjoint region, and soft merges — through a fresh engine, with a
/// full merge at each of three phase boundaries. Returns everything the
/// fast path could possibly perturb: the final stats, a final-memory
/// snapshot, and the sum of every cycle count the engine handed back.
fn run_stream(seed: u64, cores: usize, fast: bool) -> (Stats, Vec<u32>, u64) {
    let cores = cores.max(1);
    let mut cfg = MachineConfig::test_small();
    cfg.cores = cores;
    cfg.fast_path = fast;
    let mut s = MemSystem::new(cfg).unwrap();
    let cdata = s.alloc_lines(64 * 128);
    let coh = s.alloc_lines(64 * 128);
    for core in 0..cores {
        s.merge_init(core, 0, handle(AddU32));
        s.merge_init(core, 1, handle(AddU32));
    }
    let mut rng = Rng::new(seed);
    let mut cycles = 0u64;
    for _phase in 0..3 {
        for _ in 0..400 {
            let core = rng.usize_below(cores);
            let line = rng.below(128);
            match rng.below(6) {
                0 => {
                    let ty = rng.below(2) as u8;
                    let a = cdata.add(line * 64 + rng.below(16) * 4);
                    let (v, c1) = s.c_read(core, a, ty).unwrap();
                    let c2 = s.c_write(core, a, v.wrapping_add(1), ty).unwrap();
                    cycles += c1 + c2;
                }
                1 => cycles += s.soft_merge(core).unwrap(),
                2 => cycles += s.read(core, coh.add(line * 64)).unwrap().1,
                3 => cycles += s.write(core, coh.add(line * 64), rng.next_u32()).unwrap(),
                4 => {
                    let (_, c) = s.cas(core, coh.add(line * 64), 0, rng.next_u32()).unwrap();
                    cycles += c;
                }
                _ => {
                    let (_, c) = s
                        .fetch_or(core, coh.add(line * 64), rng.next_u32())
                        .unwrap();
                    cycles += c;
                }
            }
        }
        // phase boundary: every core merges its CData
        for core in 0..cores {
            cycles += s.merge_all(core).unwrap();
        }
    }
    s.flush_hot_stats();
    s.check_invariants().unwrap();
    let mut memory = Vec::with_capacity(256);
    for i in 0..128u64 {
        memory.push(s.peek(cdata.add(i * 64)));
    }
    for i in 0..128u64 {
        memory.push(s.peek(coh.add(i * 64)));
    }
    (s.stats.clone(), memory, cycles)
}

#[test]
fn fast_path_is_bit_identical_on_random_streams() {
    check_diff(
        0xFA57,
        10,
        |rng| (rng.below(u64::MAX), 1 + rng.usize_below(2)),
        |&(seed, cores)| run_stream(seed, cores, true),
        |&(seed, cores)| run_stream(seed, cores, false),
    );
}

/// The same exactness, end-to-end through the execution driver (machine
/// threads, merge-region registration, golden verification) for every
/// workload variant the repo ships: CGL, FGL, DUP, CCache, and BFS's
/// atomic variant.
#[test]
fn five_variants_bit_identical_through_the_driver() {
    let cells = [
        ("kvstore", Variant::Cgl),
        ("kvstore", Variant::Fgl),
        ("kvstore", Variant::Dup),
        ("kvstore", Variant::CCache),
        ("bfs", Variant::Atomic),
    ];
    for (name, variant) in cells {
        let spec = registry::lookup(name).unwrap();
        let bench = spec.build(&SizeSpec::new(0.25, 16 << 10, 7));
        let mut fast_cfg = MachineConfig::test_small();
        fast_cfg.fast_path = true;
        let mut slow_cfg = MachineConfig::test_small();
        slow_cfg.fast_path = false;
        let fast = bench.run_with_merge(variant, fast_cfg, None).unwrap();
        let slow = bench.run_with_merge(variant, slow_cfg, None).unwrap();
        assert!(
            fast.verified && slow.verified,
            "{name}/{} failed golden verification",
            variant.name()
        );
        assert_eq!(
            fast.stats,
            slow.stats,
            "stats diverged for {name}/{}",
            variant.name()
        );
    }
}
