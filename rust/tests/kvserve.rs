//! Acceptance pins for the kvserve serving subsystem (ISSUE 9):
//! the `serve` sweep produces a staleness-vs-throughput frontier
//! across at least three merge deadlines and all four serving
//! variants, ccache throughput dominates the atomic baseline at every
//! grid point, the measured staleness bound is monotone in the
//! deadline, and golden verification holds on both backends.

use std::collections::BTreeSet;

use ccache::coordinator::{run_serve_on, ServeOptions};
use ccache::exec::{driver, Backend, Variant};
use ccache::sim::config::MachineConfig;
use ccache::workloads::kvserve::{KvServeWorkload, ServeParams, VARIANTS};
use ccache::workloads::traffic::TrafficSpec;

fn small_cfg() -> MachineConfig {
    MachineConfig::test_small().with_cores(2)
}

fn quick_opts() -> ServeOptions {
    ServeOptions {
        quick: true,
        jobs: 1,
        native_check: false,
        ..ServeOptions::default()
    }
}

#[test]
fn frontier_spans_three_deadlines_and_four_variants() {
    let res = run_serve_on(small_cfg(), quick_opts());

    let deadlines: BTreeSet<usize> = res.cells.iter().map(|c| c.deadline).collect();
    assert!(
        deadlines.len() >= 3,
        "frontier needs >= 3 deadlines, got {deadlines:?}"
    );
    for &(skew, deadline) in res.grid_points().iter() {
        let variants: BTreeSet<&str> = res
            .cells
            .iter()
            .filter(|c| c.skew == skew && c.deadline == deadline)
            .map(|c| c.variant.name())
            .collect();
        assert_eq!(
            variants,
            ["atomic", "ccache", "dup", "fgl"].into_iter().collect(),
            "grid point ({skew}, {deadline}) missing variants"
        );
    }
    assert!(res.cells.iter().all(|c| c.verified), "a cell diverged");
    assert!(!res.frontier().is_empty());
}

#[test]
fn ccache_throughput_dominates_atomic_across_the_grid() {
    let res = run_serve_on(small_cfg(), quick_opts());
    assert_eq!(
        res.ccache_wins_vs_atomic(),
        res.grid_points().len(),
        "ccache lost to atomic somewhere on the quick grid"
    );
}

#[test]
fn staleness_bound_is_monotone_as_the_deadline_tightens() {
    let res = run_serve_on(small_cfg(), quick_opts());
    for &(skew, _) in res.grid_points().iter() {
        let mut cc: Vec<_> = res
            .cells
            .iter()
            .filter(|c| c.skew == skew && c.variant == Variant::CCache)
            .collect();
        cc.sort_by_key(|c| c.deadline);
        for w in cc.windows(2) {
            assert!(
                w[0].staleness_max <= w[1].staleness_max,
                "skew {skew}: tightening the deadline ({} -> {}) raised the bound",
                w[1].deadline,
                w[0].deadline
            );
            assert!(w[0].staleness_mean <= w[1].staleness_mean + 1e-9);
        }
        for c in &cc {
            assert!(c.staleness_max <= c.deadline as u64);
        }
    }
}

#[test]
fn golden_verification_holds_on_both_backends() {
    let p = ServeParams {
        traffic: TrafficSpec {
            keys_per_tenant: 64,
            ..ServeParams::default().traffic
        },
        epochs: 2,
        accesses_per_key: 4,
        merge_deadline: 32,
    };
    let cfg = small_cfg();
    for &variant in VARIANTS.iter() {
        for backend in [Backend::Sim, Backend::Native] {
            let wl = KvServeWorkload::new(p.clone());
            let r = driver::run_on(&wl, backend, variant, cfg.clone())
                .unwrap_or_else(|e| panic!("{variant:?} on {backend:?}: {e}"));
            assert!(r.verified, "{variant:?} on {backend:?} failed verification");
            let st = wl.staleness().expect("verify computed staleness");
            match variant {
                Variant::Fgl | Variant::Atomic => assert_eq!(st.max_ops, 0),
                Variant::CCache => assert!(st.max_ops <= p.merge_deadline as u64),
                _ => {}
            }
        }
    }
}
