//! Statistical and determinism guarantees for the kvserve trace engine.
//!
//! The serving-tier results are only meaningful if (a) the zipf key
//! sampler actually follows the analytic zipf mass at the skews the
//! tests and sweeps use, and (b) a trace is a pure function of
//! `(spec, core, epoch)` — identical on every backend, every run, with
//! the skew-drift schedule included. Chi-square goodness-of-fit pins
//! the first; replay + cross-backend golden verification pin the
//! second.

use ccache::exec::{driver, Backend, Variant};
use ccache::sim::config::MachineConfig;
use ccache::util::rng::{Rng, Zipf};
use ccache::workloads::kvserve::{golden_counts, KvServeWorkload, ServeParams};
use ccache::workloads::traffic::{drifted_theta, zipf_pmf, Mix, TraceGen, TrafficSpec};

/// Pearson chi-square statistic of `observed` against `expected`.
fn chi_square(observed: &[u64], expected: &[f64]) -> f64 {
    observed
        .iter()
        .zip(expected)
        .map(|(&o, &e)| {
            let d = o as f64 - e;
            d * d / e
        })
        .sum()
}

/// With 63 degrees of freedom the chi-square mean is 63 and the
/// standard deviation ~11.2; 150 sits beyond any plausible tail for a
/// correct sampler while a uniform or off-by-one sampler lands in the
/// thousands. Seeds are fixed, so the test is fully deterministic.
const CHI2_BOUND_DF63: f64 = 150.0;

#[test]
fn zipf_sampler_matches_the_analytic_mass() {
    let n = 64;
    let draws = 20_000u64;
    for (seed, theta) in [(11u64, 0.6f64), (12, 0.9)] {
        let zipf = Zipf::new(n, theta);
        let mut rng = Rng::new(seed);
        let mut observed = vec![0u64; n];
        for _ in 0..draws {
            observed[zipf.sample(&mut rng)] += 1;
        }
        let expected: Vec<f64> = (0..n)
            .map(|k| draws as f64 * zipf_pmf(n, theta, k))
            .collect();
        assert!(expected.iter().all(|&e| e > 5.0), "bins too thin for GOF");
        let chi2 = chi_square(&observed, &expected);
        assert!(
            chi2 < CHI2_BOUND_DF63,
            "theta {theta}: chi2 {chi2:.1} rejects zipf fit"
        );
    }
}

#[test]
fn trace_keys_follow_the_drifted_zipf_mass() {
    // One core sees every tenant (cores = 1 makes all tenants local),
    // so conditioning requests on the tenant gives per-tenant key
    // histograms to test against that tenant's *drifted* theta.
    let spec = TrafficSpec {
        tenants: 4,
        keys_per_tenant: 64,
        shards: 4,
        mix: Mix::default(),
        base_theta: 0.6,
        skew_drift: 0.2,
        scan_len: 8,
        seed: 77,
    };
    let epoch = 3; // mid-drift: tenants sit at distinct effective thetas
    let mut gen = TraceGen::new(&spec, 0, 1, epoch);
    let mut hist = vec![vec![0u64; spec.keys_per_tenant]; spec.tenants];
    let draws = 60_000usize;
    for _ in 0..draws {
        let r = gen.next_request();
        hist[r.tenant][r.key - r.tenant * spec.keys_per_tenant] += 1;
    }
    for t in 0..spec.tenants {
        let total: u64 = hist[t].iter().sum();
        assert!(total > 8_000, "tenant {t} undersampled ({total})");
        let theta = drifted_theta(&spec, t, epoch);
        let expected: Vec<f64> = (0..spec.keys_per_tenant)
            .map(|k| total as f64 * zipf_pmf(spec.keys_per_tenant, theta, k))
            .collect();
        let chi2 = chi_square(&hist[t], &expected);
        assert!(
            chi2 < CHI2_BOUND_DF63,
            "tenant {t} (theta {theta:.3}): chi2 {chi2:.1} rejects drifted fit"
        );
    }
}

#[test]
fn traces_replay_identically_with_the_drift_schedule() {
    let spec = TrafficSpec {
        tenants: 3,
        keys_per_tenant: 32,
        shards: 3,
        mix: Mix::parse("60:30:10").unwrap(),
        base_theta: 0.7,
        skew_drift: 0.3,
        scan_len: 4,
        seed: 1234,
    };
    for epoch in 0..6 {
        for core in 0..2 {
            let mut a = TraceGen::new(&spec, core, 2, epoch);
            let mut b = TraceGen::new(&spec, core, 2, epoch);
            for _ in 0..500 {
                assert_eq!(a.next_request(), b.next_request());
            }
        }
    }
    // The drift schedule itself is replayable spec-to-spec.
    let twin = spec;
    for epoch in 0..16 {
        for t in 0..spec.tenants {
            assert_eq!(
                drifted_theta(&spec, t, epoch),
                drifted_theta(&twin, t, epoch)
            );
        }
    }
}

/// The end-to-end determinism claim: the same spec yields the same
/// golden update counts, and both backends reproduce that golden —
/// i.e. the trace a native thread replays is bit-identical to the one
/// the simulator replays.
#[test]
fn sim_and_native_replay_the_same_trace() {
    let p = ServeParams {
        traffic: TrafficSpec {
            tenants: 4,
            keys_per_tenant: 64,
            shards: 4,
            mix: Mix::default(),
            base_theta: 0.6,
            skew_drift: 0.2,
            scan_len: 8,
            seed: 9090,
        },
        epochs: 3,
        accesses_per_key: 4,
        merge_deadline: 16,
    };
    let cores = 2;
    assert_eq!(golden_counts(&p, cores), golden_counts(&p, cores));

    let cfg = MachineConfig::test_small().with_cores(cores);
    for variant in [Variant::Fgl, Variant::CCache] {
        for backend in [Backend::Sim, Backend::Native] {
            let wl = KvServeWorkload::new(p.clone());
            let r = driver::run_on(&wl, backend, variant, cfg.clone())
                .unwrap_or_else(|e| panic!("{variant:?} on {backend:?}: {e}"));
            assert!(r.verified, "{variant:?} on {backend:?} diverged from golden");
        }
    }
}
