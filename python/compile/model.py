# Layer 2: the paper's numeric compute graphs in JAX, calling the Layer-1
# Pallas kernels so everything lowers into one HLO module per artifact.
# Python runs only at build time (make artifacts); rust loads the HLO text
# through PJRT and executes it on the request path.
"""L2 model graphs for ccache-rs.

Exported entry points (see aot.py for the AOT shapes):

  merge_batch_<kind>  -- batched line merges, kind in merge_kernels.MERGES
  kmeans_step         -- assignment (Pallas) + one-hot accumulation (XLA)
  pagerank_iter       -- damped dense matvec (Pallas)

The functions return tuples so the HLO root is a tuple (the rust side
unwraps with to_tuple*; lowering uses return_tuple=True).
"""

import jax.numpy as jnp

from .kernels import kmeans as kmeans_k
from .kernels import merge_kernels as mk
from .kernels import pagerank as pr_k


# -- merges -----------------------------------------------------------------


def merge_batch_add(src, upd, mem):
    return (mk.merge_add(src, upd, mem),)


def merge_batch_sat(src, upd, mem, thresh):
    return (mk.merge_sat(src, upd, mem, thresh),)


def merge_batch_cmul(src, upd, mem):
    return (mk.merge_cmul(src, upd, mem),)


def merge_batch_bitor(src, upd, mem):
    return (mk.merge_bitor(src, upd, mem),)


def merge_batch_min(src, upd, mem):
    return (mk.merge_min(src, upd, mem),)


def merge_batch_max(src, upd, mem):
    return (mk.merge_max(src, upd, mem),)


def merge_batch_approx(src, upd, mem, mask):
    return (mk.merge_approx(src, upd, mem, mask),)


# -- K-Means ----------------------------------------------------------------


def kmeans_step(points, centroids, mask):
    """One iteration of numeric work: Pallas assignment + one-hot matmul
    accumulation (scatter-free; the [N,K] one-hot @ [N,D] points contraction
    is MXU-shaped). mask [N] f32 zeroes padding rows.

    Returns (assign [N] i32, sums [K, D] f32, counts [K] f32).
    """
    k = centroids.shape[0]
    assign, _ = kmeans_k.kmeans_assign(points, centroids)
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    onehot = onehot * mask[:, None]
    sums = onehot.T @ points
    counts = jnp.sum(onehot, axis=0)
    return (assign, sums, counts)


# -- PageRank ---------------------------------------------------------------


def pagerank_iter(adj_norm, rank, out_deg_inv):
    return (pr_k.pagerank_iter(adj_norm, rank, out_deg_inv),)
