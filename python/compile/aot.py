# Emit HLO text (NOT .serialize()) — jax>=0.5 serialized HloModuleProtos
# carry 64-bit instruction ids that xla_extension 0.5.1 rejects
# (`proto.id() <= INT_MAX`); the HLO *text* parser reassigns ids and
# round-trips cleanly. See /opt/xla-example/load_hlo/.
"""AOT compile path: lower every L2 entry point to artifacts/<name>.hlo.txt.

This is the only place Python touches the system. `make artifacts` runs it
once; the rust binary then loads the HLO text through the PJRT CPU client
(rust/src/runtime/) and Python never appears on the request path.

The shapes below are the executable-specialization contract with rust —
rust/src/runtime/artifacts.rs must agree (it parses the emitted
manifest.txt to verify at load time).

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import LINE_WORDS

# ---- the AOT shape contract (mirrored in rust/src/runtime/artifacts.rs) ----
MERGE_BATCH = 256  # rows per merge executable; rust pads partial batches
KMEANS_N = 2048
KMEANS_D = 16
KMEANS_K = 16
PAGERANK_V = 1024


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


LINE = (MERGE_BATCH, LINE_WORDS)

# name -> (fn, arg specs). Keep in sync with the rust ArtifactKind enum.
ENTRIES = {
    "merge_add": (model.merge_batch_add, [_spec(LINE)] * 3),
    "merge_sat": (model.merge_batch_sat, [_spec(LINE)] * 3 + [_spec((1, 1))]),
    "merge_cmul": (model.merge_batch_cmul, [_spec(LINE)] * 3),
    "merge_bitor": (
        model.merge_batch_bitor,
        [_spec(LINE, jnp.int32)] * 3,
    ),
    "merge_min": (model.merge_batch_min, [_spec(LINE)] * 3),
    "merge_max": (model.merge_batch_max, [_spec(LINE)] * 3),
    "merge_approx": (
        model.merge_batch_approx,
        [_spec(LINE)] * 3 + [_spec((MERGE_BATCH, 1))],
    ),
    "kmeans_step": (
        model.kmeans_step,
        [
            _spec((KMEANS_N, KMEANS_D)),
            _spec((KMEANS_K, KMEANS_D)),
            _spec((KMEANS_N,)),
        ],
    ),
    "pagerank_iter": (
        model.pagerank_iter,
        [
            _spec((PAGERANK_V, PAGERANK_V)),
            _spec((PAGERANK_V,)),
            _spec((PAGERANK_V,)),
        ],
    ),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name):
    fn, specs = ENTRIES[name]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def manifest_line(name):
    _, specs = ENTRIES[name]
    args = ";".join(
        f"{s.dtype}[{','.join(str(d) for d in s.shape)}]" for s in specs
    )
    return f"{name} {args}"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = args.only.split(",") if args.only else list(ENTRIES)
    for name in names:
        text = lower_entry(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    man = os.path.join(args.out_dir, "manifest.txt")
    with open(man, "w") as f:
        f.write(f"# ccache-rs AOT manifest: entry <dtype[shape];...>\n")
        f.write(f"merge_batch={MERGE_BATCH}\n")
        f.write(f"line_words={LINE_WORDS}\n")
        f.write(f"kmeans={KMEANS_N},{KMEANS_D},{KMEANS_K}\n")
        f.write(f"pagerank_v={PAGERANK_V}\n")
        for name in ENTRIES:
            f.write(manifest_line(name) + "\n")
    print(f"wrote {man}")


if __name__ == "__main__":
    main()
