"""Layer-1 Pallas kernels for ccache-rs.

Every kernel here is authored with ``pl.pallas_call(..., interpret=True)``
so it lowers to plain HLO that the CPU PJRT plugin (and the rust `xla`
crate) can execute. Real-TPU lowering would emit Mosaic custom-calls the
CPU client cannot run; interpret mode is the correctness/compile target,
and TPU performance is estimated analytically in DESIGN.md / EXPERIMENTS.md.

Modules:
  merge_kernels -- batched cache-line merge functions (the paper's
                   software-defined merges, Section 3.2 / 6.3)
  kmeans        -- K-Means assignment/accumulation step (Section 5.1)
  pagerank      -- one damped PageRank iteration (Section 5.1)
  ref           -- pure-jnp oracles for all of the above
"""
