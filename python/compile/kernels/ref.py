"""Pure-jnp correctness oracles for every Layer-1 Pallas kernel.

Each function here is the *specification*: the Pallas kernels in
``merge_kernels.py`` / ``kmeans.py`` / ``pagerank.py`` must match these
bit-for-bit (integers) or to float tolerance. pytest enforces the match.

Shapes follow the CCache hardware model: a cache line is 64 bytes, i.e.
16 f32 words (or 16 i32 words, or 8 interleaved complex numbers). A merge
batch is ``[B, 16]``: one row per source-buffer entry being merged.
"""

import jax.numpy as jnp

LINE_WORDS = 16  # 64-byte cache line = 16 x 4-byte words


# ---------------------------------------------------------------------------
# Merge functions (paper Section 3.2, 4.5, 6.3).
#
# Signature convention, mirroring the CCache merge registers: each merge
# takes the preserved `src` copy, the core's `upd` copy and the in-memory
# `mem` copy, and returns the new memory value. All are [B, 16].
# ---------------------------------------------------------------------------


def merge_add(src, upd, mem):
    """Additive merge: apply the core's delta to memory (Fig. 3)."""
    return mem + (upd - src)


def merge_sat(src, upd, mem, thresh):
    """Saturating/thresholding additive merge (Section 4.5, 6.3).

    The conditional must observe the *in-memory* value, not the updated
    copy: the delta is applied and then clamped to `thresh` from above.
    `thresh` has shape [1, 1] (a scalar staged like a merge register).
    """
    return jnp.minimum(mem + (upd - src), thresh)


def merge_cmul(src, upd, mem):
    """Complex-multiply merge (Section 6.3).

    Lines hold 8 complex numbers as interleaved (re, im) f32 pairs. The
    core's multiplicative factor is upd / src; memory is multiplied by it.
    """
    sr, si = src[:, 0::2], src[:, 1::2]
    ur, ui = upd[:, 0::2], upd[:, 1::2]
    mr, mi = mem[:, 0::2], mem[:, 1::2]
    # factor = upd / src; a zero source makes it undefined -> identity
    # (mirrors rust merge/funcs.rs CmulF32's zero-denominator guard)
    den = sr * sr + si * si
    zero = den == 0.0
    safe_den = jnp.where(zero, 1.0, den)
    fr = jnp.where(zero, 1.0, (ur * sr + ui * si) / safe_den)
    fi = jnp.where(zero, 0.0, (ui * sr - ur * si) / safe_den)
    # out = mem * factor
    outr = mr * fr - mi * fi
    outi = mr * fi + mi * fr
    out = jnp.stack([outr, outi], axis=-1).reshape(mem.shape)
    return out


def merge_bitor(src, upd, mem):
    """Bitwise-OR merge (BFS bitmap, Section 5.1). int32 lanes.

    OR is idempotent, so merging the whole updated copy (which includes
    the source bits) is correct: mem | upd.
    """
    del src
    return mem | upd


def merge_min(src, upd, mem):
    """Minimum merge (e.g. shortest-path relaxations). Idempotent."""
    del src
    return jnp.minimum(mem, upd)


def merge_max(src, upd, mem):
    """Maximum merge. Idempotent."""
    del src
    return jnp.maximum(mem, upd)


def merge_approx(src, upd, mem, mask):
    """Approximate merge (Section 6.3): drop a line's update when its mask
    entry is 0. The mask is drawn by the *caller* from a programmer-chosen
    binomial distribution (no RNG inside the kernel -- the hardware analog
    samples outside the merge unit). mask: [B, 1] f32 of {0.0, 1.0}.
    """
    return mem + mask * (upd - src)


# ---------------------------------------------------------------------------
# K-Means step (paper Section 5.1).
# ---------------------------------------------------------------------------


def kmeans_assign(points, centroids):
    """Assign each point to the nearest centroid.

    points: [N, D] f32, centroids: [K, D] f32 -> (assign [N] i32, dist2 [N] f32)
    Distances are expanded into matmul form (MXU-friendly):
    ||p - c||^2 = ||p||^2 - 2 p.c + ||c||^2.
    """
    p2 = jnp.sum(points * points, axis=1, keepdims=True)  # [N,1]
    c2 = jnp.sum(centroids * centroids, axis=1)[None, :]  # [1,K]
    cross = points @ centroids.T  # [N,K]
    d2 = p2 - 2.0 * cross + c2
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return assign, jnp.min(d2, axis=1)


def kmeans_accumulate(points, assign, mask, k):
    """Per-cluster component-wise sums and counts (the merge payload).

    points: [N, D], assign: [N] i32, mask: [N] f32 {0,1} (padding mask).
    Returns (sums [K, D], counts [K]). One-hot matmul form, no scatter.
    """
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    onehot = onehot * mask[:, None]
    sums = onehot.T @ points
    counts = jnp.sum(onehot, axis=0)
    return sums, counts


def kmeans_step(points, centroids, mask):
    """One full K-Means iteration worth of numeric work."""
    assign, _ = kmeans_assign(points, centroids)
    sums, counts = kmeans_accumulate(points, assign, mask, centroids.shape[0])
    return assign, sums, counts


# ---------------------------------------------------------------------------
# PageRank iteration (paper Section 5.1). Dense-adjacency formulation used
# by the AOT artifact (the simulator's CSR PageRank is the timing model;
# this kernel is the numeric hot loop for the graph-analytics example).
# ---------------------------------------------------------------------------


def pagerank_iter(adj_norm, rank, damping=0.85):
    """rank' = (1-d)/V + d * A_norm @ rank.

    adj_norm: [V, V] f32 column-normalized adjacency (adj_norm[v, u] =
    1/outdeg(u) if edge u->v else 0; dangling columns spread uniformly).
    rank: [V] f32.
    """
    v = rank.shape[0]
    return (1.0 - damping) / v + damping * (adj_norm @ rank)
