"""K-Means assignment kernel (Pallas, Layer 1).

The assignment phase is the compute hot-spot of the paper's K-Means
benchmark (Section 5.1): every point computes its distance to every
cluster center. We tile points into [BLOCK_N, D] VMEM blocks while the
full centroid tile [K, D] stays resident, and expand the distance into
matmul form so the cross term hits the MXU:

    ||p - c||^2 = ||p||^2 - 2 p.c + ||c||^2

The per-cluster accumulation (the merge payload) is a one-hot matmul at
Layer 2 (model.py) -- scatter-free, also MXU-shaped.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256


def _assign_kernel(pts_ref, cen_ref, assign_ref, dist_ref):
    pts = pts_ref[...]  # [BN, D]
    cen = cen_ref[...]  # [K, D]
    p2 = jnp.sum(pts * pts, axis=1, keepdims=True)
    c2 = jnp.sum(cen * cen, axis=1)[None, :]
    d2 = p2 - 2.0 * (pts @ cen.T) + c2
    assign_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=1)


def kmeans_assign(points, centroids):
    """points [N, D] f32, centroids [K, D] f32 ->
    (assign [N] i32, dist2 [N] f32)."""
    n, d = points.shape
    k, d2 = centroids.shape
    assert d == d2
    block_n = min(BLOCK_N, n)
    assert n % block_n == 0, f"N={n} not a multiple of {block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(points, centroids)
