"""Batched cache-line merge kernels (Pallas, Layer 1).

The CCache hardware merges one 64-byte line at a time through the merge
registers (paper Section 4.2). In software we batch all pending line
merges of a core (or a DUP reduction over a whole array) into a [B, 16]
tile and run one kernel invocation -- the VMEM/BlockSpec analogue of the
merge-register staging. Rows are independent, so padding rows are ignored
by the caller.

All kernels: inputs src/upd/mem [B, 16] -> merged mem' [B, 16].
interpret=True throughout (see kernels/__init__.py docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LINE_WORDS

# Rows per grid step. 128 rows x 16 words x 4 bytes x 4 buffers = 32 KiB,
# comfortably inside a TPU core's VMEM with double-buffering headroom.
BLOCK_B = 128


def _row_spec(block_b):
    return pl.BlockSpec((block_b, LINE_WORDS), lambda i: (i, 0))


def _grid(b, block_b):
    assert b % block_b == 0, f"batch {b} not a multiple of block {block_b}"
    return (b // block_b,)


def _line_merge_call(kernel, ops, extra_specs=(), extra_args=(), dtype=jnp.float32):
    """Shared pallas_call wiring for [B,16]-shaped line merges."""
    src, upd, mem = ops
    b = src.shape[0]
    block_b = min(BLOCK_B, b)
    specs = [_row_spec(block_b)] * 3 + list(extra_specs)
    return pl.pallas_call(
        kernel,
        grid=_grid(b, block_b),
        in_specs=specs,
        out_specs=_row_spec(block_b),
        out_shape=jax.ShapeDtypeStruct((b, LINE_WORDS), dtype),
        interpret=True,
    )(src, upd, mem, *extra_args)


# -- add --------------------------------------------------------------------


def _add_kernel(src_ref, upd_ref, mem_ref, out_ref):
    out_ref[...] = mem_ref[...] + (upd_ref[...] - src_ref[...])


def merge_add(src, upd, mem):
    return _line_merge_call(_add_kernel, (src, upd, mem))


# -- saturating add ---------------------------------------------------------


def _sat_kernel(src_ref, upd_ref, mem_ref, thresh_ref, out_ref):
    applied = mem_ref[...] + (upd_ref[...] - src_ref[...])
    out_ref[...] = jnp.minimum(applied, thresh_ref[0, 0])


def merge_sat(src, upd, mem, thresh):
    """thresh: [1, 1] f32 scalar staged like a merge register."""
    return _line_merge_call(
        _sat_kernel,
        (src, upd, mem),
        extra_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0))],
        extra_args=(thresh,),
    )


# -- complex multiply -------------------------------------------------------


def _cmul_kernel(src_ref, upd_ref, mem_ref, out_ref):
    src, upd, mem = src_ref[...], upd_ref[...], mem_ref[...]
    sr, si = src[:, 0::2], src[:, 1::2]
    ur, ui = upd[:, 0::2], upd[:, 1::2]
    mr, mi = mem[:, 0::2], mem[:, 1::2]
    den = sr * sr + si * si
    # zero source -> undefined factor: apply the identity instead of
    # poisoning the line with NaN (mirrors rust merge/funcs.rs CmulF32)
    zero = den == 0.0
    safe_den = jnp.where(zero, 1.0, den)
    fr = jnp.where(zero, 1.0, (ur * sr + ui * si) / safe_den)
    fi = jnp.where(zero, 0.0, (ui * sr - ur * si) / safe_den)
    outr = mr * fr - mi * fi
    outi = mr * fi + mi * fr
    out_ref[...] = jnp.stack([outr, outi], axis=-1).reshape(mem.shape)


def merge_cmul(src, upd, mem):
    return _line_merge_call(_cmul_kernel, (src, upd, mem))


# -- bitwise OR (int32) -----------------------------------------------------


def _bitor_kernel(src_ref, upd_ref, mem_ref, out_ref):
    del src_ref  # OR is idempotent; the source bits are harmless to re-apply
    out_ref[...] = mem_ref[...] | upd_ref[...]


def merge_bitor(src, upd, mem):
    return _line_merge_call(_bitor_kernel, (src, upd, mem), dtype=jnp.int32)


# -- min / max --------------------------------------------------------------


def _min_kernel(src_ref, upd_ref, mem_ref, out_ref):
    del src_ref
    out_ref[...] = jnp.minimum(mem_ref[...], upd_ref[...])


def merge_min(src, upd, mem):
    return _line_merge_call(_min_kernel, (src, upd, mem))


def _max_kernel(src_ref, upd_ref, mem_ref, out_ref):
    del src_ref
    out_ref[...] = jnp.maximum(mem_ref[...], upd_ref[...])


def merge_max(src, upd, mem):
    return _line_merge_call(_max_kernel, (src, upd, mem))


# -- approximate (update-dropping) add --------------------------------------


def _approx_kernel(src_ref, upd_ref, mem_ref, mask_ref, out_ref):
    delta = upd_ref[...] - src_ref[...]
    out_ref[...] = mem_ref[...] + mask_ref[...] * delta


def merge_approx(src, upd, mem, mask):
    """mask: [B, 1] f32 of {0.0, 1.0}; 0 drops the line's update."""
    b = src.shape[0]
    block_b = min(BLOCK_B, b)
    return _line_merge_call(
        _approx_kernel,
        (src, upd, mem),
        extra_specs=[pl.BlockSpec((block_b, 1), lambda i: (i, 0))],
        extra_args=(mask,),
    )


# Registry used by aot.py and the tests. Entries: name -> (fn, n_extra, dtype)
# where n_extra counts trailing non-line operands (thresh / mask).
MERGES = {
    "add": (merge_add, 0, jnp.float32),
    "sat": (merge_sat, 1, jnp.float32),
    "cmul": (merge_cmul, 0, jnp.float32),
    "bitor": (merge_bitor, 0, jnp.int32),
    "min": (merge_min, 0, jnp.float32),
    "max": (merge_max, 0, jnp.float32),
    "approx": (merge_approx, 1, jnp.float32),
}
