"""PageRank iteration kernel (Pallas, Layer 1).

Dense-adjacency damped matvec, tiled over destination vertices: each grid
step loads a [BLOCK_V, V] stripe of the normalized adjacency into VMEM
against the full contribution vector. The simulator's CSR PageRank models
the cache/coherence behaviour; this kernel is the numeric hot loop used by
the graph-analytics example and the end-to-end driver.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_V = 128


def _pr_kernel(adj_ref, contrib_ref, base_ref, out_ref):
    # rank'[blk] = base + d * A[blk, :] @ contrib ; base/damping staged as
    # a [1, 2] scalar tile: base_ref[0,0] = (1-d)/V, base_ref[0,1] = d.
    adj = adj_ref[...]  # [BV, V]
    contrib = contrib_ref[...]  # [V]
    out_ref[...] = base_ref[0, 0] + base_ref[0, 1] * (adj @ contrib)


def pagerank_iter(adj_norm, rank, out_deg_inv, damping=0.85):
    """adj_norm [V, V] f32 (adj_norm[v, u] = 1 if edge u->v else 0),
    rank [V] f32, out_deg_inv [V] f32 (1/outdeg, 0 for dangling handled
    by caller's normalization). Returns rank' [V] f32.

    The contribution vector rank * out_deg_inv is formed at Layer 2 /
    caller; here we take rank and out_deg_inv separately so the kernel
    fuses the scaling.
    """
    v = rank.shape[0]
    block_v = min(BLOCK_V, v)
    assert v % block_v == 0
    contrib = rank * out_deg_inv
    base = jnp.array([[(1.0 - damping) / v, damping]], dtype=jnp.float32)
    return pl.pallas_call(
        _pr_kernel,
        grid=(v // block_v,),
        in_specs=[
            pl.BlockSpec((block_v, v), lambda i: (i, 0)),
            pl.BlockSpec((v,), lambda i: (0,)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_v,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((v,), jnp.float32),
        interpret=True,
    )(adj_norm, contrib, base)
