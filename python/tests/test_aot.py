"""AOT lowering smoke tests: every entry lowers to parseable HLO text."""

import pytest

from compile import aot


@pytest.mark.parametrize("name", sorted(aot.ENTRIES))
def test_entry_lowers_to_hlo_text(name):
    text = aot.lower_entry(name)
    assert "ENTRY" in text
    assert "HloModule" in text
    # tuple root (return_tuple=True) so rust unwraps with to_tuple*
    assert "ROOT" in text


def test_manifest_lines_cover_all_entries():
    for name in aot.ENTRIES:
        line = aot.manifest_line(name)
        assert line.startswith(name + " ")
        assert "[" in line


def test_shape_contract_constants():
    # the contract mirrored in rust/src/runtime/artifacts.rs
    assert aot.MERGE_BATCH == 256
    assert aot.LINE_WORDS == 16
    assert aot.KMEANS_N % 256 == 0
    assert aot.PAGERANK_V % 128 == 0
