"""Pallas K-Means assignment kernel + L2 step vs the jnp oracle and a
brute-force numpy reference."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import kmeans as kk
from compile.kernels import ref


@pytest.fixture
def rng():
    return np.random.default_rng(7)


def brute_assign(points, centroids):
    d = ((points[:, None, :] - centroids[None, :, :]) ** 2).sum(-1)
    return d.argmin(1).astype(np.int32)


@pytest.mark.parametrize("n,d,k", [(256, 4, 3), (512, 16, 16), (2048, 16, 16), (256, 2, 8)])
def test_assign_matches_brute_force(rng, n, d, k):
    pts = rng.normal(size=(n, d)).astype(np.float32) * 10
    cen = rng.normal(size=(k, d)).astype(np.float32) * 10
    got, _ = kk.kmeans_assign(jnp.asarray(pts), jnp.asarray(cen))
    want = brute_assign(pts, cen)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("n,d,k", [(256, 8, 4), (2048, 16, 16)])
def test_assign_matches_ref(rng, n, d, k):
    pts = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    got_a, got_d = kk.kmeans_assign(pts, cen)
    want_a, want_d = ref.kmeans_assign(pts, cen)
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-3)


def test_step_accumulation_with_mask(rng):
    n, d, k = 512, 16, 16
    pts = rng.normal(size=(n, d)).astype(np.float32)
    cen = rng.normal(size=(k, d)).astype(np.float32)
    mask = np.ones(n, np.float32)
    mask[400:] = 0.0  # padding rows must not contribute
    assign, sums, counts = model.kmeans_step(
        jnp.asarray(pts), jnp.asarray(cen), jnp.asarray(mask)
    )
    a = brute_assign(pts, cen)
    np.testing.assert_array_equal(np.asarray(assign), a)
    want_sums = np.zeros((k, d), np.float32)
    want_counts = np.zeros(k, np.float32)
    for i in range(400):
        want_sums[a[i]] += pts[i]
        want_counts[a[i]] += 1
    np.testing.assert_allclose(np.asarray(sums), want_sums, rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(counts), want_counts)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n=st.sampled_from([256, 512, 1024]),
    k=st.sampled_from([2, 5, 16]),
    d=st.sampled_from([2, 8, 16]),
)
def test_assign_hypothesis_sweep(seed, n, k, d):
    r = np.random.default_rng(seed)
    pts = jnp.asarray(r.normal(size=(n, d)).astype(np.float32))
    cen = jnp.asarray(r.normal(size=(k, d)).astype(np.float32))
    got, _ = kk.kmeans_assign(pts, cen)
    np.testing.assert_array_equal(np.asarray(got), brute_assign(np.asarray(pts), np.asarray(cen)))


def test_converges_on_separated_clusters(rng):
    """Full Lloyd iterations through the L2 step recover well-separated
    cluster centers -- the end-to-end numeric sanity the simulator's K-Means
    workload relies on."""
    k, d, per = 4, 8, 128
    true = rng.normal(size=(k, d)).astype(np.float32) * 50
    pts = np.concatenate(
        [true[i] + rng.normal(size=(per, d)).astype(np.float32) for i in range(k)]
    )
    n = pts.shape[0]
    mask = jnp.ones(n, jnp.float32)
    # seed one initial center inside each true cluster (k-means++-lite);
    # random init can drop a cluster, which is a Lloyd property, not a
    # kernel bug.
    cen = pts[[i * per for i in range(k)]].copy()
    for _ in range(10):
        _, sums, counts = model.kmeans_step(
            jnp.asarray(pts), jnp.asarray(cen), mask
        )
        counts = np.asarray(counts)
        new = np.asarray(sums) / np.maximum(counts[:, None], 1.0)
        cen = np.where(counts[:, None] > 0, new, cen)  # keep empty clusters
    # every true center should be close to some recovered center
    for i in range(k):
        dmin = np.min(((cen - true[i]) ** 2).sum(1))
        assert dmin < d * 1.0, f"center {i} not recovered (d2={dmin})"
