"""Pallas merge kernels vs the pure-jnp oracle (ref.py).

This is the CORE Layer-1 correctness signal: every software-defined merge
function the paper demonstrates (Section 3.2 / 6.3) must match its
specification for arbitrary batches. Hypothesis sweeps batch sizes and
value distributions; dedicated tests pin the algebraic properties the
paper relies on (commutativity / serializability of merges).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import merge_kernels as mk
from compile.kernels import ref

LINE = ref.LINE_WORDS
BATCHES = [1, 2, 8, 128, 256, 384]


def rand_lines(rng, b, scale=100.0):
    return jnp.asarray(
        rng.uniform(-scale, scale, size=(b, LINE)).astype(np.float32)
    )


def rand_int_lines(rng, b):
    return jnp.asarray(rng.integers(0, 2**31 - 1, size=(b, LINE), dtype=np.int32))


@pytest.fixture
def rng():
    return np.random.default_rng(0xCCAC4E)


# ---------------------------------------------------------------------------
# kernel == oracle, across batch sizes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b", BATCHES)
def test_add_matches_ref(rng, b):
    src, upd, mem = (rand_lines(rng, b) for _ in range(3))
    got = mk.merge_add(src, upd, mem)
    want = ref.merge_add(src, upd, mem)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("b", BATCHES)
def test_sat_matches_ref(rng, b):
    src, upd, mem = (rand_lines(rng, b) for _ in range(3))
    thresh = jnp.asarray([[37.5]], dtype=jnp.float32)
    got = mk.merge_sat(src, upd, mem, thresh)
    want = ref.merge_sat(src, upd, mem, thresh)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert float(jnp.max(got)) <= 37.5 + 1e-6


@pytest.mark.parametrize("b", BATCHES)
def test_cmul_matches_ref(rng, b):
    # keep sources away from 0 so upd/src is well-conditioned
    src = rand_lines(rng, b) + jnp.where(rand_lines(rng, b) > 0, 150.0, -150.0)
    upd, mem = rand_lines(rng, b), rand_lines(rng, b)
    got = mk.merge_cmul(src, upd, mem)
    want = ref.merge_cmul(src, upd, mem)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_cmul_zero_source_applies_identity(rng):
    # regression: src = 0+0i used to divide by zero and emit NaN; both
    # paths now apply the identity factor (matches rust CmulF32's guard)
    src = jnp.zeros((4, LINE), dtype=jnp.float32)
    upd, mem = rand_lines(rng, 4), rand_lines(rng, 4)
    for fn in (mk.merge_cmul, ref.merge_cmul):
        out = fn(src, upd, mem)
        assert bool(jnp.all(jnp.isfinite(out)))
        np.testing.assert_allclose(out, mem, rtol=1e-6)


@pytest.mark.parametrize("b", BATCHES)
def test_bitor_matches_ref(rng, b):
    src, upd, mem = (rand_int_lines(rng, b) for _ in range(3))
    got = mk.merge_bitor(src, upd, mem)
    want = ref.merge_bitor(src, upd, mem)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("b", BATCHES)
def test_min_max_match_ref(rng, b):
    src, upd, mem = (rand_lines(rng, b) for _ in range(3))
    np.testing.assert_array_equal(mk.merge_min(src, upd, mem), ref.merge_min(src, upd, mem))
    np.testing.assert_array_equal(mk.merge_max(src, upd, mem), ref.merge_max(src, upd, mem))


@pytest.mark.parametrize("b", BATCHES)
def test_approx_matches_ref(rng, b):
    src, upd, mem = (rand_lines(rng, b) for _ in range(3))
    mask = jnp.asarray(
        rng.integers(0, 2, size=(b, 1)).astype(np.float32)
    )
    got = mk.merge_approx(src, upd, mem, mask)
    want = ref.merge_approx(src, upd, mem, mask)
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# algebraic properties the paper's correctness argument needs (Section 3.1):
# applying two cores' merges in either order gives the same memory result.
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    data=st.lists(
        st.floats(min_value=-1e3, max_value=1e3, allow_nan=False, width=32),
        min_size=LINE * 4,
        max_size=LINE * 4,
    )
)
def test_add_merge_order_independent(data):
    a = np.asarray(data, dtype=np.float32).reshape(4, LINE)
    mem0 = jnp.asarray(a[0:1])
    src = jnp.asarray(a[1:2])
    upd_a, upd_b = jnp.asarray(a[2:3]), jnp.asarray(a[3:4])
    # core A then core B
    m1 = ref.merge_add(src, upd_b, ref.merge_add(src, upd_a, mem0))
    # core B then core A
    m2 = ref.merge_add(src, upd_a, ref.merge_add(src, upd_b, mem0))
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-3)
    # and the pallas kernel agrees with the composed oracle
    k1 = mk.merge_add(src, upd_b, mk.merge_add(src, upd_a, mem0))
    np.testing.assert_allclose(k1, m1, rtol=1e-5, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    bits=st.lists(
        st.integers(min_value=0, max_value=2**31 - 1),
        min_size=LINE * 3,
        max_size=LINE * 3,
    )
)
def test_bitor_merge_order_independent(bits):
    a = np.asarray(bits, dtype=np.int32).reshape(3, LINE)
    mem0, upd_a, upd_b = (jnp.asarray(a[i : i + 1]) for i in range(3))
    src = jnp.zeros_like(mem0)
    m1 = ref.merge_bitor(src, upd_b, ref.merge_bitor(src, upd_a, mem0))
    m2 = ref.merge_bitor(src, upd_a, ref.merge_bitor(src, upd_b, mem0))
    np.testing.assert_array_equal(m1, m2)


@settings(max_examples=20, deadline=None)
@given(
    vals=st.lists(
        st.floats(min_value=-1e4, max_value=1e4, allow_nan=False, width=32),
        min_size=LINE * 3,
        max_size=LINE * 3,
    )
)
def test_min_merge_idempotent_and_commutative(vals):
    a = np.asarray(vals, dtype=np.float32).reshape(3, LINE)
    mem0, upd_a, upd_b = (jnp.asarray(a[i : i + 1]) for i in range(3))
    src = jnp.zeros_like(mem0)
    m1 = ref.merge_min(src, upd_b, ref.merge_min(src, upd_a, mem0))
    m2 = ref.merge_min(src, upd_a, ref.merge_min(src, upd_b, mem0))
    np.testing.assert_array_equal(m1, m2)
    # idempotent: merging the same update twice changes nothing
    np.testing.assert_array_equal(ref.merge_min(src, upd_a, m1), m1)


# ---------------------------------------------------------------------------
# hypothesis sweep: batch size x random values for the add kernel (the one
# every benchmark uses), checking kernel == oracle at every size.
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 64, 128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1.0, 1e3, 1e6]),
)
def test_add_kernel_hypothesis_sweep(b, seed, scale):
    r = np.random.default_rng(seed)
    src, upd, mem = (
        jnp.asarray(r.uniform(-scale, scale, (b, LINE)).astype(np.float32))
        for _ in range(3)
    )
    np.testing.assert_allclose(
        mk.merge_add(src, upd, mem),
        ref.merge_add(src, upd, mem),
        rtol=1e-6,
        atol=scale * 1e-5,
    )


def test_sat_threshold_conditional_observes_memory(rng):
    """Paper Section 4.5: the saturation conditional must clamp based on the
    *merged memory* value. If memory is already at threshold, any positive
    delta must leave it at the threshold."""
    b = 8
    thresh = jnp.asarray([[100.0]], dtype=jnp.float32)
    mem = jnp.full((b, LINE), 100.0, dtype=jnp.float32)
    src = jnp.zeros((b, LINE), dtype=jnp.float32)
    upd = jnp.full((b, LINE), 55.0, dtype=jnp.float32)  # positive delta
    out = mk.merge_sat(src, upd, mem, thresh)
    np.testing.assert_array_equal(np.asarray(out), np.full((b, LINE), 100.0, np.float32))
