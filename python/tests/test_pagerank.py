"""Pallas PageRank iteration kernel vs oracle and numpy power iteration."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import pagerank as pk
from compile.kernels import ref


def random_graph(rng, v, p=0.05):
    adj = (rng.uniform(size=(v, v)) < p).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    return adj  # adj[dst, src] = 1 if edge src->dst


def norm_inputs(adj):
    outdeg = adj.sum(axis=0)  # column sums = out-degrees
    inv = np.where(outdeg > 0, 1.0 / np.maximum(outdeg, 1), 0.0).astype(np.float32)
    return inv


@pytest.mark.parametrize("v", [128, 256, 1024])
def test_iter_matches_ref(v):
    rng = np.random.default_rng(v)
    adj = random_graph(rng, v)
    inv = norm_inputs(adj)
    rank = np.full(v, 1.0 / v, np.float32)
    got = pk.pagerank_iter(jnp.asarray(adj), jnp.asarray(rank), jnp.asarray(inv))
    want = ref.pagerank_iter(jnp.asarray(adj * inv[None, :]), jnp.asarray(rank))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-6)


def test_power_iteration_converges():
    v = 256
    rng = np.random.default_rng(1)
    adj = random_graph(rng, v, p=0.1)
    inv = norm_inputs(adj)
    rank = jnp.full((v,), 1.0 / v, jnp.float32)
    prev = None
    for _ in range(50):
        rank = pk.pagerank_iter(jnp.asarray(adj), rank, jnp.asarray(inv))
        cur = np.asarray(rank)
        if prev is not None and np.abs(cur - prev).sum() < 1e-7:
            break
        prev = cur
    # converged distribution: non-negative
    assert (np.asarray(rank) >= 0).all()
    delta = np.abs(np.asarray(rank) - prev).sum()
    assert delta < 1e-5


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1), v=st.sampled_from([128, 256]))
def test_iter_hypothesis_sweep(seed, v):
    rng = np.random.default_rng(seed)
    adj = random_graph(rng, v, p=0.08)
    inv = norm_inputs(adj)
    rank = rng.uniform(size=v).astype(np.float32)
    rank /= rank.sum()
    got = pk.pagerank_iter(jnp.asarray(adj), jnp.asarray(rank), jnp.asarray(inv))
    want = ref.pagerank_iter(jnp.asarray(adj * inv[None, :]), jnp.asarray(rank))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-6)
