import os
import sys

# Make `import compile.*` work regardless of pytest rootdir.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
