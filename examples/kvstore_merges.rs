//! Section 6.3 — flexible, software-defined merge functions.
//!
//! Runs the key-value store with three different merge functions (plain
//! add, saturating add, complex multiplication) and shows that CCache's
//! advantage holds across all of them — the paper's core argument
//! against fixed-function hardware (COUP).
//!
//!     cargo run --release --example kvstore_merges

use ccache::coordinator::scaled_config;
use ccache::exec::Variant;
use ccache::util::bench::Table;
use ccache::workloads::kvstore::{KvMerge, KvParams};
use ccache::workloads::Benchmark;

fn main() {
    let cfg = scaled_config();
    let keys = cfg.llc.size_bytes / 8; // WS ~ half the LLC
    let mut t = Table::new(
        "KV store: speedup vs FGL per merge function",
        &["merge fn", "FGL cycles", "DUP", "CCACHE"],
    );
    for merge in [KvMerge::Add, KvMerge::Sat { max: 12 }, KvMerge::Cmul] {
        let p = KvParams {
            keys: if merge == KvMerge::Cmul { keys / 2 } else { keys },
            accesses_per_key: 16,
            seed: 7,
            merge,
            zipf_theta: 0.0,
        };
        let bench = Benchmark::Kv(p);
        eprintln!("running {}...", bench.name());
        let fgl = bench.run(Variant::Fgl, cfg);
        fgl.assert_verified();
        let dup = bench.run(Variant::Dup, cfg);
        dup.assert_verified();
        let cc = bench.run(Variant::CCache, cfg);
        cc.assert_verified();
        t.row(&[
            merge.name().to_string(),
            fgl.cycles().to_string(),
            format!("{:.2}x", fgl.cycles() as f64 / dup.cycles() as f64),
            format!("{:.2}x", fgl.cycles() as f64 / cc.cycles() as f64),
        ]);
    }
    t.print();
    println!(
        "CCache's benefit persists across arbitrary merge semantics —\n\
         saturating and complex-arithmetic updates would not fit a fixed\n\
         hardware operation set (Section 6.3 / COUP comparison)."
    );
}
